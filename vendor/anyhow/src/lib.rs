//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environment for this repository has no network access and no
//! crates.io registry, so the workspace vendors the small subset of the
//! anyhow API the `spork` crate actually uses:
//!
//! * [`Error`] / [`Result`] — a context-chain error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Semantics follow upstream anyhow: `Display` prints the outermost
//! context message, the `{:#}` alternate form prints the whole chain
//! joined by `": "`, and `Debug` prints the chain as a `Caused by:` list.
//! Unlike upstream, sources are eagerly rendered to strings (no backtrace
//! capture, no downcasting) — sufficient for error *reporting*, which is
//! all this workspace does with errors.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. The first entry is the outermost context; the
/// last is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context message (what `.context(..)` does).
    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like upstream anyhow: any std error converts into `Error`. `Error`
// itself deliberately does NOT implement `std::error::Error`, which keeps
// this blanket impl coherent alongside the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment for `Result` and `Option`, mirroring anyhow's
/// `Context` trait.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_and_debug() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "loading config".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("loading config"));
        assert!(dbg.contains("Caused by"));
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("sevens are right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", inner(7).unwrap_err()), "sevens are right out");
        assert_eq!(format!("{}", inner(1).unwrap_err()), "fell through with 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_chains_nest() {
        let e = Err::<(), Error>(anyhow!("root"))
            .context("middle")
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(e.chain().count(), 3);
    }
}
