"""L2: the served application and scheduler compute graphs.

The paper's workload class is latency-sensitive inference (Table 2 —
CNN/RNN serving). We serve a small MLP classifier in two builds:

* ``app_fpga`` — the L1 Pallas-tiled implementation (the "FPGA worker"'s
  specialized datapath);
* ``app_cpu`` — the pure-jnp reference (the "CPU worker"'s software
  implementation).

Both bake the same deterministically-initialized weights, so the two
worker kinds are interchangeable per the hybrid-computing contract (same
request -> same answer), which the rust serving tests assert.

``predictor_scores`` is Spork's Alg-2 expectation (see
``kernels/predictor.py``).

This module is build-time only: ``aot.py`` lowers the jitted functions to
HLO text artifacts; Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import mlp as mlp_kernel
from .kernels import predictor as predictor_kernel
from .kernels import ref

# Served-model geometry (MXU-aligned: multiples of 128 in N/K).
D_IN = 128
D_HIDDEN = 256
D_OUT = 128
LAYERS = (D_IN, D_HIDDEN, D_OUT)
BATCH_SIZES = (8, 32)
WEIGHT_SEED = 20230618


def init_params(seed: int = WEIGHT_SEED):
    """Deterministic He-initialized weights shared by both builds."""
    key = jax.random.PRNGKey(seed)
    params = []
    for d_in, d_out in zip(LAYERS[:-1], LAYERS[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (d_in, d_out), jnp.float32) * (2.0 / d_in) ** 0.5
        b = jnp.zeros((d_out,), jnp.float32)
        params.append((w, b))
    return params


def app_fpga(x):
    """FPGA-worker build: Pallas-tiled MLP with baked weights."""
    return (mlp_kernel.mlp(x, init_params()),)


def app_cpu(x):
    """CPU-worker build: reference MLP with the same baked weights."""
    return (ref.mlp_ref(x, init_params()),)


def predictor_scores(probs, bins, cands, knobs):
    """Spork Alg-2 expected scores (Pallas build)."""
    return (predictor_kernel.predictor_scores(probs, bins, cands, knobs),)


def artifact_specs():
    """Everything aot.py lowers: (name, fn, example_args)."""
    specs = []
    for batch in BATCH_SIZES:
        x = jax.ShapeDtypeStruct((batch, D_IN), jnp.float32)
        specs.append((f"app_fpga_b{batch}", app_fpga, (x,)))
        specs.append((f"app_cpu_b{batch}", app_cpu, (x,)))
    specs.append(
        (
            "predictor",
            predictor_scores,
            (
                jax.ShapeDtypeStruct((predictor_kernel.NUM_BINS,), jnp.float32),
                jax.ShapeDtypeStruct((predictor_kernel.NUM_BINS,), jnp.float32),
                jax.ShapeDtypeStruct((predictor_kernel.NUM_CANDS,), jnp.float32),
                jax.ShapeDtypeStruct((predictor_kernel.NUM_KNOBS,), jnp.float32),
            ),
        )
    )
    return specs
