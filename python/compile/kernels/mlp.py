"""L1 Pallas kernel: tiled dense layers for the served MLP (the "FPGA
bitstream" of the reproduction — see DESIGN.md §Hardware-Adaptation).

The paper's FPGA worker runs a specialized spatial datapath. On the TPU
abstraction this maps to:

* the DSP-slice array -> the MXU systolic tile: the inner ``jnp.dot`` is
  shaped to (TM, K) x (K, TN) with TN a multiple of 128 and accumulation
  in float32 (``preferred_element_type``), which lowers onto the MXU on
  real hardware;
* BRAM-staged streaming -> the BlockSpec HBM<->VMEM schedule: the grid
  walks output tiles; for each (i, j) step Pallas stages an (TM, K) x
  (K, TN) working set into VMEM, computes, and writes the (TM, TN) tile
  back — the same producer/consumer pipelining the FPGA would express
  with line buffers.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO for both testing and the AOT
artifacts. Real-TPU VMEM/MXU characteristics are *estimated* analytically
(see ``vmem_footprint`` and EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-aligned output tile, full-K staging.
TILE_M = 8
TILE_N = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activate: bool):
    """One output tile: o = act(x @ w + b).

    x_ref: (TM, K) — the row panel for this grid step.
    w_ref: (K, TN) — the weight column panel.
    b_ref: (1, TN) — bias slice.
    o_ref: (TM, TN).
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if activate:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def linear(x, w, b, activate: bool, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """Tiled dense layer via pallas_call.

    Shapes: x (M, K), w (K, N), b (N,) with M % tile_m == 0 and
    N % tile_n == 0 (the model pads to MXU-friendly sizes at build time).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % tile_m == 0, f"M={m} not a multiple of {tile_m}"
    assert n % tile_n == 0, f"N={n} not a multiple of {tile_n}"
    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        functools.partial(_linear_kernel, activate=activate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, -1))


def mlp(x, params, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """MLP inference through the Pallas layers (matches ``ref.mlp_ref``)."""
    h = x
    for i, (w, b) in enumerate(params):
        h = linear(h, w, b, activate=i + 1 < len(params), tile_m=tile_m, tile_n=tile_n)
    return h


def vmem_footprint(tile_m: int, tile_n: int, k: int, dtype_bytes: int = 4) -> int:
    """Bytes of VMEM one grid step touches (x panel + w panel + bias +
    output tile + f32 accumulator). The schedule must stay well under the
    ~16 MiB VMEM of a TPU core; reported in EXPERIMENTS.md §Perf."""
    x_panel = tile_m * k * dtype_bytes
    w_panel = k * tile_n * dtype_bytes
    bias = tile_n * dtype_bytes
    out_tile = tile_m * tile_n * dtype_bytes
    acc = tile_m * tile_n * 4
    return x_panel + w_panel + bias + out_tile + acc


def mxu_utilization_estimate(tile_m: int, tile_n: int, k: int) -> float:
    """Estimated MXU lane utilization of the inner dot: fraction of the
    128x128 systolic array the (tile_m x tile_n) output tile keeps busy,
    discounted by K-dimension pipeline fill (K / (K + 128))."""
    lane_fill = min(tile_n, 128) / 128.0
    sublane_fill = min(tile_m, 128) / 128.0
    pipeline = k / (k + 128.0)
    return lane_fill * sublane_fill * pipeline
