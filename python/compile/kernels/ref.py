"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package must match its oracle to float32
tolerance under pytest (``python/tests/``). The oracles are also the "CPU
worker" implementation of the served application: the FPGA worker runs the
Pallas-specialized artifact, the CPU worker runs this reference lowered as
plain jnp (see ``model.py``).
"""

import jax.numpy as jnp


def linear_ref(x, w, b, activate: bool):
    """One dense layer: x @ w + b, optional ReLU."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return jnp.maximum(y, 0.0) if activate else y


def mlp_ref(x, params):
    """MLP inference over a list of (w, b) layers; ReLU between layers,
    linear output head."""
    h = x
    for i, (w, b) in enumerate(params):
        h = linear_ref(h, w, b, activate=i + 1 < len(params))
    return h


def predictor_scores_ref(probs, bins, cands, knobs):
    """Expected objective score per candidate allocation (Alg 2 inner loop).

    Args:
      probs:  (B,) occurrence probability per histogram bin (0-padded).
      bins:   (B,) worker-count value of each bin.
      cands:  (C,) candidate allocation counts.
      knobs:  (9,) packed parameters:
              [T_s, B_f, I_f, B_c, S, c_f, c_c, w_E, w_C]
              (powers in watts, costs in $/s, weights unitless).

    Returns:
      (C,) expected score per candidate, normalized to busy-FPGA-interval
      units (w_E * E / (B_f*T_s) + w_C * C / (c_f*T_s)), matching rust's
      `Objective::score`.
    """
    ts, bf, if_, bc, s, cf, cc, we, wc = [knobs[i] for i in range(9)]
    n = bins[None, :]  # (1, B)
    c = cands[:, None]  # (C, 1)
    over = c >= n
    # Over-allocation: n busy FPGAs + (c-n) idle FPGAs.
    e_over = (c - n) * if_ * ts + n * bf * ts
    cost_over = c * cf * ts
    # Under-allocation: c busy FPGAs + burst CPUs for the gap.
    cpu_secs = (n - c) * s * ts
    e_under = c * bf * ts + cpu_secs * bc
    cost_under = c * cf * ts + cpu_secs * cc
    e = jnp.where(over, e_over, e_under)
    cost = jnp.where(over, cost_over, cost_under)
    score = we * e / (bf * ts) + wc * cost / (cf * ts)
    return jnp.sum(probs[None, :] * score, axis=1)
