"""L1 Pallas kernel: Spork's Alg-2 expected-score evaluation.

The predictor's hot loop is an O(candidates x bins) reduction: for every
candidate allocation count, the probability-weighted objective over the
conditional histogram. The rust coordinator's scalar implementation walks
this loop per tick; this kernel vectorizes it so the scheduler itself can
be offloaded through the same AOT path as the served model (DESIGN.md
"XLA-offloaded predictor").

Shapes are fixed at AOT time (histograms are padded with prob=0 bins and
candidates with repeats), so one compiled executable serves every tick.

The sequential spin-up amortization of Alg 2 (a data-dependent walk over
the lifetime map) stays in rust; the kernel computes the distribution
expectation, which dominates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed AOT shapes: up to 64 candidates x 64 histogram bins.
NUM_CANDS = 64
NUM_BINS = 64
NUM_KNOBS = 9  # [T_s, B_f, I_f, B_c, S, c_f, c_c, w_E, w_C]


def _scores_kernel(probs_ref, bins_ref, cands_ref, knobs_ref, o_ref):
    """Single-block kernel: the full (C, B) expectation in VMEM.

    C x B = 64 x 64 floats (~16 KiB working set) — far under VMEM; no
    grid needed. Broadcasting shapes the compute as (C, B) elementwise
    plus a lane reduction, which maps onto the VPU.
    """
    ts = knobs_ref[0, 0]
    bf = knobs_ref[0, 1]
    if_ = knobs_ref[0, 2]
    bc = knobs_ref[0, 3]
    s = knobs_ref[0, 4]
    cf = knobs_ref[0, 5]
    cc = knobs_ref[0, 6]
    we = knobs_ref[0, 7]
    wc = knobs_ref[0, 8]

    n = bins_ref[...]  # (1, B)
    c = cands_ref[...].reshape(NUM_CANDS, 1)  # (C, 1)
    probs = probs_ref[...]  # (1, B)

    over = c >= n
    e_over = (c - n) * if_ * ts + n * bf * ts
    cost_over = c * cf * ts
    cpu_secs = (n - c) * s * ts
    e_under = c * bf * ts + cpu_secs * bc
    cost_under = c * cf * ts + cpu_secs * cc
    e = jnp.where(over, e_over, e_under)
    cost = jnp.where(over, cost_over, cost_under)
    score = we * e / (bf * ts) + wc * cost / (cf * ts)
    o_ref[...] = jnp.sum(probs * score, axis=1).reshape(NUM_CANDS, 1)


def predictor_scores(probs, bins, cands, knobs):
    """Expected score per candidate (see ``ref.predictor_scores_ref``).

    probs, bins: (NUM_BINS,); cands: (NUM_CANDS,); knobs: (NUM_KNOBS,).
    Returns (NUM_CANDS,).
    """
    assert probs.shape == (NUM_BINS,)
    assert bins.shape == (NUM_BINS,)
    assert cands.shape == (NUM_CANDS,)
    assert knobs.shape == (NUM_KNOBS,)
    out = pl.pallas_call(
        _scores_kernel,
        out_shape=jax.ShapeDtypeStruct((NUM_CANDS, 1), jnp.float32),
        interpret=True,
    )(
        probs.reshape(1, NUM_BINS).astype(jnp.float32),
        bins.reshape(1, NUM_BINS).astype(jnp.float32),
        cands.reshape(NUM_CANDS, 1).astype(jnp.float32),
        knobs.reshape(1, NUM_KNOBS).astype(jnp.float32),
    )
    return out.reshape(NUM_CANDS)
