"""AOT lowering: jitted L2 functions -> HLO *text* artifacts for the rust
runtime (``rust/src/runtime``).

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Functions are lowered with ``return_tuple=True`` so the rust side unwraps
with ``to_tuple1()``.

Usage:  python -m compile.aot --out-dir ../artifacts
Incremental: skips lowering when artifacts are newer than the python
sources (make drives this through file timestamps anyway).
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def arg_manifest(example_args):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
    ]


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="lower just one artifact by name"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "model": {
            "layers": list(model.LAYERS),
            "batch_sizes": list(model.BATCH_SIZES),
            "weight_seed": model.WEIGHT_SEED,
        },
        "artifacts": {},
    }
    for name, fn, example_args in model.artifact_specs():
        if args.only and name != args.only:
            continue
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_manifest(example_args),
            "hlo_bytes": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
