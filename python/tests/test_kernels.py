"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes and value ranges; assert_allclose against ref.py
is the core correctness signal for the kernels that end up in the AOT
artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp as mlp_kernel
from compile.kernels import predictor as predictor_kernel
from compile.kernels import ref


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestLinear:
    @settings(max_examples=20, deadline=None)
    @given(
        m_tiles=st.integers(1, 4),
        n_tiles=st.integers(1, 2),
        k=st.sampled_from([32, 128, 256]),
        activate=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_over_shapes(self, m_tiles, n_tiles, k, activate, seed):
        m = m_tiles * mlp_kernel.TILE_M
        n = n_tiles * mlp_kernel.TILE_N
        x = rand(seed, m, k)
        w = rand(seed + 1, k, n) * 0.1
        b = rand(seed + 2, n)
        got = mlp_kernel.linear(x, w, b, activate)
        want = ref.linear_ref(x, w, b, activate)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu_clamps(self):
        x = -jnp.ones((8, 32), jnp.float32)
        w = jnp.eye(32, 128, dtype=jnp.float32)
        b = jnp.zeros((128,), jnp.float32)
        y = mlp_kernel.linear(x, w, b, activate=True)
        assert float(jnp.min(y)) == 0.0

    def test_rejects_misaligned_shapes(self):
        x = jnp.zeros((7, 32), jnp.float32)  # 7 % TILE_M != 0
        w = jnp.zeros((32, 128), jnp.float32)
        b = jnp.zeros((128,), jnp.float32)
        with pytest.raises(AssertionError):
            mlp_kernel.linear(x, w, b, activate=False)

    @settings(max_examples=8, deadline=None)
    @given(
        tile_m=st.sampled_from([4, 8, 16]),
        tile_n=st.sampled_from([128, 256]),
        seed=st.integers(0, 1000),
    )
    def test_tile_size_invariance(self, tile_m, tile_n, seed):
        """Output must not depend on the BlockSpec tiling."""
        m, k, n = 16, 64, 256
        x = rand(seed, m, k)
        w = rand(seed + 1, k, n) * 0.1
        b = rand(seed + 2, n)
        base = mlp_kernel.linear(x, w, b, True)
        tiled = mlp_kernel.linear(x, w, b, True, tile_m=tile_m, tile_n=tile_n)
        np.testing.assert_allclose(base, tiled, rtol=1e-5, atol=1e-6)


class TestMlp:
    @settings(max_examples=10, deadline=None)
    @given(batch_tiles=st.integers(1, 4), seed=st.integers(0, 1000))
    def test_full_mlp_matches_ref(self, batch_tiles, seed):
        from compile import model

        batch = batch_tiles * mlp_kernel.TILE_M
        params = model.init_params()
        x = rand(seed, batch, model.D_IN)
        got = mlp_kernel.mlp(x, params)
        want = ref.mlp_ref(x, params)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_fpga_and_cpu_builds_agree(self):
        """The hybrid-computing contract: both worker kinds compute the
        same function."""
        from compile import model

        x = rand(7, 8, model.D_IN)
        (fpga,) = model.app_fpga(x)
        (cpu,) = model.app_cpu(x)
        np.testing.assert_allclose(fpga, cpu, rtol=1e-4, atol=1e-4)


class TestVmemEstimates:
    def test_footprint_under_vmem(self):
        """The chosen schedule must fit a TPU core's ~16 MiB VMEM."""
        from compile import model

        for k in (model.D_IN, model.D_HIDDEN):
            bytes_ = mlp_kernel.vmem_footprint(
                mlp_kernel.TILE_M, mlp_kernel.TILE_N, k
            )
            assert bytes_ < 16 * 1024 * 1024 / 4, bytes_  # <25% of VMEM

    def test_mxu_estimate_monotone_in_tiles(self):
        lo = mlp_kernel.mxu_utilization_estimate(4, 128, 128)
        hi = mlp_kernel.mxu_utilization_estimate(128, 128, 128)
        assert hi > lo
        assert 0.0 < lo < 1.0 and 0.0 < hi <= 1.0


class TestPredictorKernel:
    def _knobs(self, we=1.0, wc=0.0):
        # Paper defaults: Ts=10, Bf=50, If=20, Bc=150, S=2,
        # cf/cc in $/s.
        return jnp.array(
            [10.0, 50.0, 20.0, 150.0, 2.0, 0.982 / 3600, 0.668 / 3600, we, wc],
            jnp.float32,
        )

    def _padded(self, bins_probs):
        bins = np.zeros(predictor_kernel.NUM_BINS, np.float32)
        probs = np.zeros(predictor_kernel.NUM_BINS, np.float32)
        for i, (n, p) in enumerate(bins_probs):
            bins[i] = n
            probs[i] = p
        cands = np.arange(predictor_kernel.NUM_CANDS, dtype=np.float32)
        return jnp.array(probs), jnp.array(bins), jnp.array(cands)

    @settings(max_examples=20, deadline=None)
    @given(
        n_bins=st.integers(1, 8),
        seed=st.integers(0, 10_000),
        we=st.floats(0.0, 1.0),
    )
    def test_matches_ref(self, n_bins, seed, we):
        rng = np.random.RandomState(seed)
        raw = rng.rand(n_bins)
        probs_v = raw / raw.sum()
        bins_probs = [(float(rng.randint(0, 40)), float(p)) for p in probs_v]
        probs, bins, cands = self._padded(bins_probs)
        knobs = self._knobs(we=we, wc=1.0 - we)
        got = predictor_kernel.predictor_scores(probs, bins, cands, knobs)
        want = ref.predictor_scores_ref(probs, bins, cands, knobs)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_argmin_matches_exact_history(self):
        """Deterministic history at n=5: candidate 5 must win (energy)."""
        probs, bins, cands = self._padded([(5.0, 1.0)])
        scores = predictor_kernel.predictor_scores(
            probs, bins, cands, self._knobs()
        )
        assert int(jnp.argmin(scores)) == 5

    def test_energy_leans_higher_than_cost(self):
        """50/50 split between needing 2 and 10: energy-weighted argmin
        >= cost-weighted argmin (rust predictor asserts the same)."""
        probs, bins, cands = self._padded([(2.0, 0.5), (10.0, 0.5)])
        e = predictor_kernel.predictor_scores(probs, bins, cands, self._knobs(1.0, 0.0))
        c = predictor_kernel.predictor_scores(probs, bins, cands, self._knobs(0.0, 1.0))
        assert int(jnp.argmin(e)) >= int(jnp.argmin(c))
        assert int(jnp.argmin(e)) == 10
