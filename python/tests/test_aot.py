"""AOT path tests: every artifact spec lowers to parseable HLO text with
the right entry signature, and the lowered modules run correctly through
the XLA client (the same numerics the rust runtime will see)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def specs():
    return {name: (fn, args) for name, fn, args in model.artifact_specs()}


def test_artifact_roster(specs):
    names = set(specs)
    for batch in model.BATCH_SIZES:
        assert f"app_fpga_b{batch}" in names
        assert f"app_cpu_b{batch}" in names
    assert "predictor" in names


def test_hlo_text_structure(specs):
    fn, args = specs[f"app_fpga_b{model.BATCH_SIZES[0]}"]
    text = aot.to_hlo_text(fn, args)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: root must be a tuple.
    assert "tuple" in text
    # Batch-8 input shape appears in the entry signature.
    assert f"f32[{model.BATCH_SIZES[0]},{model.D_IN}]" in text


def test_lowered_module_runs_and_matches_eager(specs):
    """Compile the lowered module (the artifact source-of-truth) and
    compare against eager execution. The HLO-text → PJRT round trip is
    covered on the rust side (`rust/tests/runtime_artifacts.rs`), which is
    the consumer of the text format."""
    fn, args = specs[f"app_cpu_b{model.BATCH_SIZES[0]}"]
    x = jax.random.normal(jax.random.PRNGKey(0), args[0].shape, jnp.float32)
    compiled = jax.jit(fn).lower(*args).compile()
    (got,) = compiled(x)
    (want,) = fn(x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )

def test_fpga_and_cpu_artifacts_same_signature(specs):
    """Both worker builds must accept identical inputs (interchangeable
    execution is the hybrid-computing premise)."""
    for batch in model.BATCH_SIZES:
        _, a_fpga = specs[f"app_fpga_b{batch}"]
        _, a_cpu = specs[f"app_cpu_b{batch}"]
        assert [a.shape for a in a_fpga] == [a.shape for a in a_cpu]
        assert [a.dtype for a in a_fpga] == [a.dtype for a in a_cpu]


def test_cli_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out-dir", d, "--only", "predictor"]
        try:
            assert aot.main() == 0
        finally:
            sys.argv = argv
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert "predictor" in manifest["artifacts"]
        entry = manifest["artifacts"]["predictor"]
        hlo = open(os.path.join(d, entry["file"])).read()
        assert "HloModule" in hlo
        assert entry["args"][0]["shape"] == [64]


def test_manifest_arg_shapes(specs):
    fn, args = specs["predictor"]
    m = aot.arg_manifest(args)
    assert m[0]["shape"] == [64] and m[2]["shape"] == [64]
    assert m[3]["shape"] == [9]
    assert all(a["dtype"] == "float32" for a in m)
