//! Scalable exact solver for the §3 fluid model: dynamic programming over
//! the FPGA-count trajectory.
//!
//! Key structural facts (DESIGN.md §5): with T_s = A_f the MILP's spin-up
//! persistence constraint is vacuous, and with CPU overheads negligible
//! (0.75 J vs 500 J) the per-interval remainder cost is local given the
//! FPGA count. The only inter-interval coupling is FPGA alloc/dealloc
//! energy, so
//!
//! `V_t(y) = min_{y'} [ V_{t-1}(y') + trans(y' → y) ] + stage_t(y)`
//!
//! is exact over integer FPGA counts. The transition scan exploits the
//! structure `trans = a·max(y-y',0) + d·max(y'-y,0)` with two running-min
//! sweeps, giving O(T·Y) instead of O(T·Y²).

use super::fluid::{FluidInstance, PlatformMode};
use crate::sched::Objective;

#[derive(Clone, Debug)]
pub struct OptResult {
    pub energy: f64,
    pub cost: f64,
    /// FPGA counts per interval.
    pub trajectory: Vec<u32>,
    pub mode: PlatformMode,
}

impl OptResult {
    pub fn energy_efficiency(&self, inst: &FluidInstance) -> f64 {
        inst.ideal_energy() / self.energy
    }
    pub fn relative_cost(&self, inst: &FluidInstance) -> f64 {
        self.cost / inst.ideal_cost()
    }
}

/// Solve the fluid instance optimally under `obj` and `mode`.
pub fn solve(inst: &FluidInstance, mode: PlatformMode, obj: Objective) -> OptResult {
    let t_len = inst.demand_f.len();
    let p = &inst.platform;
    let ts = inst.interval;
    let e_unit = p.fpga.busy_power * ts;
    let c_unit = p.fpga.cost_per_sec() * ts;
    let score = |e: f64, c: f64| obj.w_energy * e / e_unit + obj.w_cost * c / c_unit;

    let cap: u32 = if mode == PlatformMode::CpuOnly {
        0
    } else {
        inst.demand_f.iter().fold(0.0f64, |a, &b| a.max(b)).ceil() as u32
    };
    let y_len = cap as usize + 1;

    // Normalized transition prices per worker.
    let up = score(p.fpga.spin_up_energy(), 0.0);
    let down = score(p.fpga.spin_down_energy(), 0.0);

    // V[y] after processing t intervals; start at Y=0 (boundary).
    let mut v = vec![f64::INFINITY; y_len];
    v[0] = 0.0;
    let mut choice: Vec<Vec<u32>> = Vec::with_capacity(t_len);

    let mut best_from_below = vec![0.0f64; y_len];
    let mut best_from_above = vec![0.0f64; y_len];
    let mut arg_below = vec![0u32; y_len];
    let mut arg_above = vec![0u32; y_len];

    for t in 0..t_len {
        let d = inst.demand_f[t];
        // Sweep up: best predecessor y' <= y paying `up` per unit raised.
        let mut run = f64::INFINITY;
        let mut arg = 0u32;
        for y in 0..y_len {
            let cand = v[y];
            if cand < run {
                run = cand;
                arg = y as u32;
            }
            best_from_below[y] = run;
            arg_below[y] = arg;
            run += up; // moving one step up costs `up` more
        }
        // Sweep down: best predecessor y' >= y paying `down` per unit cut.
        let mut run = f64::INFINITY;
        let mut arg = 0u32;
        for y in (0..y_len).rev() {
            let cand = v[y];
            if cand < run {
                run = cand;
                arg = y as u32;
            }
            best_from_above[y] = run;
            arg_above[y] = arg;
            run += down;
        }
        let mut nv = vec![f64::INFINITY; y_len];
        let mut ch = vec![0u32; y_len];
        for y in 0..y_len {
            if mode == PlatformMode::FpgaOnly && (y as f64) < d - 1e-9 {
                continue; // must cover all demand with FPGAs
            }
            let (e, c) = inst.stage(y as u32, d, mode);
            let stage = score(e, c);
            let (base, from) = if best_from_below[y] <= best_from_above[y] {
                (best_from_below[y], arg_below[y])
            } else {
                (best_from_above[y], arg_above[y])
            };
            nv[y] = base + stage;
            ch[y] = from;
        }
        v = nv;
        choice.push(ch);
    }
    // Terminal: deallocate everything.
    let mut best = (f64::INFINITY, 0usize);
    for y in 0..y_len {
        let total = v[y] + down * y as f64;
        if total < best.0 {
            best = (total, y);
        }
    }
    // Backtrack.
    let mut trajectory = vec![0u32; t_len];
    let mut y = best.1 as u32;
    for t in (0..t_len).rev() {
        trajectory[t] = y;
        y = choice[t][y as usize];
    }
    debug_assert_eq!(y, 0, "trajectory must start from zero");

    // Re-account the un-normalized energy and cost along the trajectory
    // (so results are exact joules/dollars, not normalized scores).
    let mut energy = 0.0;
    let mut cost = 0.0;
    let mut prev = 0u32;
    for (t, &yt) in trajectory.iter().enumerate() {
        let (te, tc) = inst.transition(prev, yt);
        let (se, sc) = inst.stage(yt, inst.demand_f[t], mode);
        energy += te + se;
        cost += tc + sc;
        prev = yt;
    }
    let (te, tc) = inst.transition(prev, 0);
    energy += te;
    cost += tc;

    OptResult {
        energy,
        cost,
        trajectory,
        mode,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn inst(demand: Vec<f64>) -> FluidInstance {
        FluidInstance {
            demand_f: demand,
            interval: 10.0,
            platform: PlatformConfig::paper_default(),
        }
    }

    #[test]
    fn steady_demand_allocates_exactly() {
        let f = inst(vec![2.0; 10]);
        let r = solve(&f, PlatformMode::Hybrid, Objective::energy());
        assert_eq!(r.trajectory, vec![2; 10]);
        // Energy: busy 2x10 intervals + one spin-up/down pair x2 workers.
        let expect = 2.0 * 50.0 * 100.0 + 2.0 * (500.0 + 5.0);
        assert!((r.energy - expect).abs() < 1e-6, "{} vs {expect}", r.energy);
    }

    #[test]
    fn short_lull_keeps_fpgas_idle() {
        // Demand 3,0,3: dealloc+realloc costs 3*(500+5) J vs idling
        // 3 workers for one interval = 3*20*10 = 600 J → idle wins.
        let f = inst(vec![3.0, 0.0, 3.0]);
        let r = solve(&f, PlatformMode::Hybrid, Objective::energy());
        assert_eq!(r.trajectory, vec![3, 3, 3]);
    }

    #[test]
    fn long_lull_deallocates() {
        // 1 FPGA, 30 intervals of zero, then 1 again: idling 30x200 J
        // exceeds 505 J realloc → drop to 0.
        let mut d = vec![1.0];
        d.extend(vec![0.0; 30]);
        d.push(1.0);
        let f = inst(d);
        let r = solve(&f, PlatformMode::Hybrid, Objective::energy());
        assert_eq!(r.trajectory[0], 1);
        assert_eq!(r.trajectory[15], 0);
        assert_eq!(r.trajectory[31], 1);
    }

    #[test]
    fn cost_objective_tolerates_cpu_leftovers() {
        // Demand 1.2: energy-opt rounds up to 2 FPGAs (CPU energy is 6x);
        // cost-opt uses 1 FPGA + CPUs (leftover 0.2 < 7.35 s threshold).
        let f = inst(vec![1.2; 20]);
        let re = solve(&f, PlatformMode::Hybrid, Objective::energy());
        let rc = solve(&f, PlatformMode::Hybrid, Objective::cost());
        assert_eq!(re.trajectory[10], 2);
        assert_eq!(rc.trajectory[10], 1);
        assert!(rc.cost < re.cost);
        assert!(re.energy < rc.energy);
    }

    #[test]
    fn fpga_only_must_cover() {
        let f = inst(vec![0.3, 2.4]);
        let r = solve(&f, PlatformMode::FpgaOnly, Objective::cost());
        assert!(r.trajectory[0] >= 1);
        assert!(r.trajectory[1] >= 3);
    }

    #[test]
    fn cpu_only_has_flat_cost_ratio() {
        let f = inst(vec![1.0, 3.0, 2.0]);
        let r = solve(&f, PlatformMode::CpuOnly, Objective::energy());
        assert_eq!(r.trajectory, vec![0, 0, 0]);
        // CPU-only relative cost = S*C_c/C_f.
        let ratio = r.relative_cost(&f);
        assert!((ratio - 2.0 * 0.668 / 0.982).abs() < 1e-9, "{ratio}");
        // Energy efficiency = B_f/S / B_c = 1/6.
        assert!((r.energy_efficiency(&f) - 1.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn hybrid_never_worse_than_homogeneous_on_objective() {
        use crate::trace::bmodel;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        for &b in &[0.5, 0.6, 0.7, 0.75] {
            let series = bmodel::bmodel_series(&mut rng, b, 64, 200.0);
            let f = inst(series);
            for obj in [Objective::energy(), Objective::cost()] {
                let h = solve(&f, PlatformMode::Hybrid, obj);
                let fo = solve(&f, PlatformMode::FpgaOnly, obj);
                let co = solve(&f, PlatformMode::CpuOnly, obj);
                let sc = |r: &OptResult| {
                    obj.w_energy * r.energy / (500.0) + obj.w_cost * r.cost / (0.982 / 360.0)
                };
                assert!(
                    sc(&h) <= sc(&fo) + 1e-6 && sc(&h) <= sc(&co) + 1e-6,
                    "hybrid dominated at b={b}"
                );
            }
        }
    }

    #[test]
    fn matches_milp_on_small_instances() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for case in 0..6 {
            let t = 3 + (case % 3);
            let demand: Vec<f64> =
                (0..t).map(|_| (rng.below(4) as f64) * 0.8).collect();
            let f = inst(demand.clone());
            for (mode, obj) in [
                (PlatformMode::Hybrid, Objective::energy()),
                (PlatformMode::Hybrid, Objective::cost()),
                (PlatformMode::FpgaOnly, Objective::energy()),
            ] {
                let dp = solve(&f, mode, obj);
                let milp = f.build_milp(mode, obj).solve(200_000);
                let milp = match milp {
                    Ok(s) => s,
                    Err(e) => panic!("milp failed on {demand:?}: {e:?}"),
                };
                let e_unit = 50.0 * 10.0;
                let c_unit = 0.982 / 3600.0 * 10.0;
                let dp_score =
                    obj.w_energy * dp.energy / e_unit + obj.w_cost * dp.cost / c_unit;
                assert!(
                    (dp_score - milp.objective).abs() < 1e-3 * (1.0 + milp.objective),
                    "case {case} {:?} {:?}: dp {dp_score} vs milp {}",
                    mode,
                    obj,
                    milp.objective
                );
            }
        }
    }
}
