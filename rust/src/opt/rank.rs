//! Rank (ski-rental) decomposition for homogeneous platforms.
//!
//! For a single worker kind and an integer demand profile `d_t`, worker
//! rank `k` must be allocated exactly in the intervals where `d_t >= k`.
//! Between two such busy stretches, the only decision is keep-idle vs
//! dealloc+realloc, decided per gap by comparing idle energy against the
//! dealloc+alloc pair — gaps are independent across ranks, so the global
//! optimum decomposes. This gives an O(T·peak) exact solver used to
//! cross-check the trajectory DP (`super::dp`) and as a fast path for
//! homogeneous Fig 2 curves.

use crate::config::WorkerParams;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RankCost {
    pub alloc_energy: f64,
    pub busy_energy: f64,
    pub idle_energy: f64,
    pub dealloc_energy: f64,
    /// Occupancy seconds (for cost): allocated worker-seconds.
    pub occupancy: f64,
}

impl RankCost {
    pub fn energy(&self) -> f64 {
        self.alloc_energy + self.busy_energy + self.idle_energy + self.dealloc_energy
    }

    pub fn cost(&self, params: &WorkerParams) -> f64 {
        self.occupancy * params.cost_per_sec()
    }
}

/// Optimal allocation cost for one worker kind serving integer demand
/// `d_t` (workers needed per interval of length `interval`). The
/// `optimize_energy` flag picks which metric the keep-idle decision
/// minimizes (energy vs occupancy cost).
pub fn solve(
    demand: &[u32],
    params: &WorkerParams,
    interval: f64,
    optimize_energy: bool,
) -> RankCost {
    let peak = demand.iter().copied().max().unwrap_or(0);
    let mut total = RankCost::default();
    let realloc_energy = params.spin_up_energy() + params.spin_down_energy();
    for k in 1..=peak {
        // Busy intervals for this rank.
        let mut last_busy: Option<usize> = None;
        let mut allocated = false;
        for (t, &d) in demand.iter().enumerate() {
            if d < k {
                continue;
            }
            match last_busy {
                None => {
                    // First allocation of this rank.
                    total.alloc_energy += params.spin_up_energy();
                    allocated = true;
                }
                Some(prev) => {
                    let gap = (t - prev - 1) as f64 * interval;
                    if gap > 0.0 {
                        let idle_e = gap * params.idle_power;
                        let idle_cost = gap * params.cost_per_sec();
                        let realloc_cost = 0.0; // occupancy stops when freed
                        let keep = if optimize_energy {
                            idle_e < realloc_energy
                        } else {
                            idle_cost < realloc_cost + 1e-30 // never keep for cost
                        };
                        if keep {
                            total.idle_energy += idle_e;
                            total.occupancy += gap;
                        } else {
                            total.dealloc_energy += params.spin_down_energy();
                            total.alloc_energy += params.spin_up_energy();
                        }
                    }
                }
            }
            total.busy_energy += params.busy_power * interval;
            total.occupancy += interval;
            last_busy = Some(t);
        }
        if allocated {
            total.dealloc_energy += params.spin_down_energy();
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkerParams;

    fn fpga() -> WorkerParams {
        WorkerParams::fpga_default()
    }

    #[test]
    fn steady_demand_single_alloc() {
        let r = solve(&[2, 2, 2], &fpga(), 10.0, true);
        assert!((r.alloc_energy - 1000.0).abs() < 1e-9); // 2 x 500
        assert!((r.busy_energy - 2.0 * 50.0 * 30.0).abs() < 1e-9);
        assert_eq!(r.idle_energy, 0.0);
        assert!((r.occupancy - 60.0).abs() < 1e-9);
    }

    #[test]
    fn short_gap_idles_long_gap_reallocates() {
        // Gap of 1 interval: idle 200 J < 505 J → keep.
        let r = solve(&[1, 0, 1], &fpga(), 10.0, true);
        assert!((r.idle_energy - 200.0).abs() < 1e-9);
        assert!((r.alloc_energy - 500.0).abs() < 1e-9);
        // Gap of 5 intervals: idle 1000 J > 505 J → realloc.
        let r = solve(&[1, 0, 0, 0, 0, 0, 1], &fpga(), 10.0, true);
        assert_eq!(r.idle_energy, 0.0);
        assert!((r.alloc_energy - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn cost_mode_never_idles() {
        let r = solve(&[1, 0, 1], &fpga(), 10.0, false);
        assert_eq!(r.idle_energy, 0.0);
        assert!((r.occupancy - 20.0).abs() < 1e-9);
    }

    #[test]
    fn ranks_layer_correctly() {
        // Demand [2,1,2]: rank 1 busy all 3; rank 2 has a 1-interval gap.
        let r = solve(&[2, 1, 2], &fpga(), 10.0, true);
        assert!((r.busy_energy - 5.0 * 50.0 * 10.0).abs() < 1e-9);
        assert!((r.idle_energy - 200.0).abs() < 1e-9); // rank 2 bridges
    }

    #[test]
    fn matches_dp_for_fpga_only_energy() {
        use crate::config::PlatformConfig;
        use crate::opt::dp;
        use crate::opt::fluid::{FluidInstance, PlatformMode};
        use crate::sched::Objective;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        for _ in 0..10 {
            let demand: Vec<u32> = (0..20).map(|_| rng.below(5) as u32).collect();
            let inst = FluidInstance {
                demand_f: demand.iter().map(|&d| d as f64).collect(),
                interval: 10.0,
                platform: PlatformConfig::paper_default(),
            };
            let dp_r = dp::solve(&inst, PlatformMode::FpgaOnly, Objective::energy());
            let rank_r = solve(&demand, &inst.platform.fpga, 10.0, true);
            assert!(
                (dp_r.energy - rank_r.energy()).abs() < 1e-6 * (1.0 + dp_r.energy),
                "dp {} vs rank {} for {demand:?}",
                dp_r.energy,
                rank_r.energy()
            );
        }
    }
}
