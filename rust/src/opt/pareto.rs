//! Pareto-frontier sweep over weighted energy/cost objectives (Fig 3):
//! for each burstiness level, solve the fluid instance optimally for a
//! ladder of objective weights and report (energy efficiency, relative
//! cost) points. Boundary weights are the energy- and cost-optimal
//! schedulers of Fig 2.

use super::fluid::{FluidInstance, PlatformMode};
use super::ranksolve;
use crate::sched::Objective;

#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub w_energy: f64,
    pub energy_efficiency: f64,
    pub relative_cost: f64,
}

/// Sweep `points` weights from cost-only (w=0) to energy-only (w=1).
/// `s_intervals` is the spin-up persistence horizon (spin_up / dt).
pub fn sweep_persist(
    inst: &FluidInstance,
    points: usize,
    s_intervals: usize,
) -> Vec<ParetoPoint> {
    assert!(points >= 2);
    (0..points)
        .map(|i| {
            let w = i as f64 / (points - 1) as f64;
            let obj = Objective {
                w_energy: w,
                w_cost: 1.0 - w,
            };
            let r = ranksolve::solve(inst, PlatformMode::Hybrid, obj, s_intervals);
            ParetoPoint {
                w_energy: w,
                energy_efficiency: r.energy_efficiency(inst),
                relative_cost: r.relative_cost(inst),
            }
        })
        .collect()
}

/// Interval-granularity sweep (persistence horizon 1).
pub fn sweep(inst: &FluidInstance, points: usize) -> Vec<ParetoPoint> {
    sweep_persist(inst, points, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::trace::bmodel;
    use crate::util::rng::Rng;

    fn bursty_instance(b: f64, seed: u64) -> FluidInstance {
        let mut rng = Rng::new(seed);
        FluidInstance {
            demand_f: bmodel::bmodel_series(&mut rng, b, 128, 1000.0),
            interval: 10.0,
            platform: PlatformConfig::paper_default(),
        }
    }

    #[test]
    fn endpoints_order_correctly() {
        let inst = bursty_instance(0.7, 3);
        let pts = sweep(&inst, 5);
        let cost_end = &pts[0]; // w_energy = 0
        let energy_end = &pts[4];
        assert!(
            energy_end.energy_efficiency >= cost_end.energy_efficiency - 1e-9,
            "energy end {} vs cost end {}",
            energy_end.energy_efficiency,
            cost_end.energy_efficiency
        );
        assert!(
            cost_end.relative_cost <= energy_end.relative_cost + 1e-9,
            "cost end {} vs energy end {}",
            cost_end.relative_cost,
            energy_end.relative_cost
        );
    }

    #[test]
    fn frontier_nontrivial_at_high_burstiness() {
        // Paper: at high burstiness energy-optimal is ~2x costlier than
        // cost-optimal. Assert a material spread (>20%).
        let inst = bursty_instance(0.75, 4);
        let pts = sweep(&inst, 5);
        let spread = pts[4].relative_cost / pts[0].relative_cost;
        assert!(spread > 1.2, "cost spread {spread}");
    }

    #[test]
    fn uniform_load_collapses_frontier() {
        let inst = bursty_instance(0.5, 5);
        let pts = sweep(&inst, 3);
        let spread = pts[2].relative_cost / pts[0].relative_cost;
        assert!(spread < 1.1, "uniform frontier should be tight, got {spread}");
    }
}
