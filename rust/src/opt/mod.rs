//! Offline pareto-optimal schedulers for the §3 idealized analysis:
//! the fluid model and its Table 3 MILP ([`fluid`]), the scalable
//! trajectory DP ([`dp`]), the homogeneous rank decomposition ([`rank`]),
//! and the weighted-objective sweep ([`pareto`], Fig 3).

pub mod dp;
pub mod fluid;
pub mod pareto;
pub mod rank;
pub mod ranksolve;

pub use dp::{solve, OptResult};
pub use fluid::{FluidInstance, PlatformMode};

use crate::cli::Args;
use crate::config::PlatformConfig;
use crate::trace::{bmodel, RateTrace};
use crate::util::rng::Rng;
use crate::util::table::{pct, ratio, sig3, Table};

/// `spork pareto`: print the Fig 3-style frontier for one burstiness.
pub fn cmd_pareto(args: &Args) -> Result<(), String> {
    let b = args.f64_or("burstiness", 0.65)?;
    let rate = args.f64_or("rate", 10_000.0)?;
    let duration = args.f64_or("duration", 3600.0)?;
    let points = args.u64_or("points", 9)? as usize;
    let seed = args.u64_or("seed", 1)?;
    let size = 0.010;

    let mut rng = Rng::new(seed);
    let rates = RateTrace::new(
        1.0,
        bmodel::bmodel_rates(&mut rng, b, duration as usize, rate),
    );
    let platform = PlatformConfig::paper_default();
    // §3 granularity: per-second fluid model; the FPGA spin-up becomes a
    // persistence horizon of spin_up/1s intervals.
    let s_intervals = platform.fpga.spin_up.ceil() as usize;
    let inst = FluidInstance::from_rates(&rates, size, 1.0, platform);
    let pts = pareto::sweep_persist(&inst, points.max(2), s_intervals);
    let mut t = Table::new(
        &format!("Pareto-optimal hybrid schedulers (b={b}, {rate} req/s, {duration}s)"),
        &["w_energy", "Energy Eff.", "Rel. Cost"],
    );
    for p in pts {
        t.row(vec![
            sig3(p.w_energy),
            pct(p.energy_efficiency),
            ratio(p.relative_cost),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
