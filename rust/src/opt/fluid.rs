//! The §3 fluid (rate-based) scheduling model and its MILP formulation
//! (paper Table 3).
//!
//! An instance is a per-interval demand series measured in **FPGA-worker
//! equivalents** (continuous): `demand_f[t] = X_t / r^f`, i.e. how many
//! busy FPGAs interval `t`'s arrivals occupy. The idealized §3 assumptions
//! apply: arrivals are known, requests finish within their interval, and
//! worker counts change instantaneously at interval boundaries (spin-up
//! energy still paid).

use crate::config::PlatformConfig;
use crate::milp::branch_bound::Milp;
use crate::milp::simplex::Cmp;
use crate::sched::Objective;
use crate::trace::RateTrace;

/// Which worker kinds the platform may use (Fig 2 compares all three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlatformMode {
    CpuOnly,
    FpgaOnly,
    Hybrid,
}

impl PlatformMode {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformMode::CpuOnly => "cpu-only",
            PlatformMode::FpgaOnly => "fpga-only",
            PlatformMode::Hybrid => "hybrid",
        }
    }
}

#[derive(Clone, Debug)]
pub struct FluidInstance {
    /// Busy-FPGA equivalents demanded per interval.
    pub demand_f: Vec<f64>,
    pub interval: f64,
    pub platform: PlatformConfig,
}

impl FluidInstance {
    /// Build from a rate trace and constant request size (the §3.2 setup).
    pub fn from_rates(
        rates: &RateTrace,
        request_size: f64,
        interval: f64,
        platform: PlatformConfig,
    ) -> Self {
        let binned = rates.rebin_to(interval);
        let per_fpga = platform.fpga.speedup / request_size; // req/s one FPGA absorbs
        let demand_f = binned.rates.iter().map(|r| r / per_fpga).collect();
        Self {
            demand_f,
            interval,
            platform,
        }
    }

    pub fn total_fpga_busy_seconds(&self) -> f64 {
        self.demand_f.iter().sum::<f64>() * self.interval
    }

    /// Idealized FPGA-only baseline (compute-only) for this instance.
    pub fn ideal_energy(&self) -> f64 {
        self.total_fpga_busy_seconds() * self.platform.fpga.busy_power
    }

    pub fn ideal_cost(&self) -> f64 {
        self.total_fpga_busy_seconds() * self.platform.fpga.cost_per_sec()
    }

    /// Stage cost of allocating `y` FPGAs in an interval with demand `d`
    /// (FPGA-equivalents): returns (energy J, cost $) excluding FPGA
    /// alloc/dealloc transitions. CPU alloc/dealloc/idle are folded in as
    /// negligible-but-nonzero per the §3 note (CPUs live only for their
    /// busy time; 0.75 J spin-ups are charged per *worker* in the
    /// transition-aware solvers and dropped here — documented in
    /// DESIGN.md).
    pub fn stage(&self, y: u32, d: f64, mode: PlatformMode) -> (f64, f64) {
        let p = &self.platform;
        let ts = self.interval;
        let y = y as f64;
        let fpga_busy = y.min(d);
        let fpga_idle = y - fpga_busy;
        let leftover_f = d - fpga_busy; // FPGA-equivalents served by CPUs
        debug_assert!(
            mode != PlatformMode::FpgaOnly || leftover_f < 1e-9,
            "FPGA-only stage with leftover demand"
        );
        let cpu_busy = leftover_f * p.fpga.speedup; // CPU-worker equivalents
        let energy = fpga_busy * p.fpga.busy_power * ts
            + fpga_idle * p.fpga.idle_power * ts
            + cpu_busy * p.cpu.busy_power * ts;
        let cost = y * p.fpga.cost_per_sec() * ts + cpu_busy * p.cpu.cost_per_sec() * ts;
        (energy, cost)
    }

    /// FPGA alloc/dealloc transition (energy J, cost $) from `y` to `y2`.
    pub fn transition(&self, y: u32, y2: u32) -> (f64, f64) {
        let p = &self.platform;
        let delta = y2.abs_diff(y) as f64;
        let per = if y2 > y {
            p.fpga.spin_up_energy()
        } else {
            p.fpga.spin_down_energy()
        };
        // Occupancy during spin-up/down is inside the interval already
        // (instantaneous-change idealization) → cost 0 here.
        (delta * per, 0.0)
    }

    /// Build the paper's Table 3 MILP for this instance under `mode` and
    /// `obj`. Suitable only for short horizons (cross-validation); the
    /// scalable path is [`super::dp`] / [`super::ranksolve`].
    pub fn build_milp(&self, mode: PlatformMode, obj: Objective) -> Milp {
        self.build_milp_persist(mode, obj, 1)
    }

    /// Table 3 MILP including the spin-up persistence constraint
    /// `Y_{t+S} >= Σ_{τ=t}^{t+S} max(Y_{τ+1} - Y_τ, 0)` with horizon
    /// `s_intervals` (vacuous at 1).
    pub fn build_milp_persist(
        &self,
        mode: PlatformMode,
        obj: Objective,
        s_intervals: usize,
    ) -> Milp {
        let p = &self.platform;
        let ts = self.interval;
        let t_len = self.demand_f.len();
        let cap = self
            .demand_f
            .iter()
            .fold(0.0f64, |a, &b| a.max(b))
            .ceil() as f64
            + 2.0;
        let mut m = Milp::new();
        // Normalization units (match Objective::score).
        let e_unit = p.fpga.busy_power * ts;
        let c_unit = p.fpga.cost_per_sec() * ts;
        let we = obj.w_energy / e_unit;
        let wc = obj.w_cost / c_unit;

        // Per interval: Yf (int), Bf, Bc (continuous); plus alloc/dealloc
        // linearization vars Af_t, Df_t for t in 0..=T (boundaries: Y_{-1}
        // = Y_T = 0).
        let mut yf = Vec::with_capacity(t_len);
        let mut bf = Vec::with_capacity(t_len);
        let mut bc = Vec::with_capacity(t_len);
        for &d in &self.demand_f {
            // Y_f cost: idle power applies to Y-B; split the energy as
            // e_i*Y + (e_b - e_i)*B to keep the objective linear.
            let y_cost = we * p.fpga.idle_power * ts + wc * p.fpga.cost_per_sec() * ts;
            let yf_hi = if mode == PlatformMode::CpuOnly { 0.0 } else { cap };
            let y = m.int_var(y_cost, 0.0, yf_hi);
            let b_cost = we * (p.fpga.busy_power - p.fpga.idle_power) * ts;
            let b = m.var(b_cost, 0.0, yf_hi);
            let bc_hi = if mode == PlatformMode::FpgaOnly {
                0.0
            } else {
                f64::INFINITY
            };
            // CPU busy worker: energy + occupancy cost (idle/alloc
            // negligible per §3 note).
            let c_cost = we * p.cpu.busy_power * ts + wc * p.cpu.cost_per_sec() * ts;
            let c = m.var(c_cost, 0.0, bc_hi);
            // Demand: B_f + B_c/S = d  (in FPGA-worker equivalents; B_c is
            // CPU workers, S CPU workers replace one FPGA).
            m.constrain(
                vec![(b, 1.0), (c, 1.0 / p.fpga.speedup)],
                Cmp::Eq,
                d,
            );
            // B_f <= Y_f
            m.constrain(vec![(b, 1.0), (y, -1.0)], Cmp::Le, 0.0);
            yf.push(y);
            bf.push(b);
            bc.push(c);
        }
        // FPGA alloc/dealloc transitions, including boundaries.
        let mut avars = Vec::with_capacity(t_len + 1);
        for t in 0..=t_len {
            let a = m.var(we * p.fpga.spin_up_energy(), 0.0, f64::INFINITY);
            let d_ = m.var(we * p.fpga.spin_down_energy(), 0.0, f64::INFINITY);
            // A_t >= Y_t - Y_{t-1} ; D_t >= Y_{t-1} - Y_t
            let mut at = vec![(a, 1.0)];
            let mut dt = vec![(d_, 1.0)];
            if t < t_len {
                at.push((yf[t], -1.0));
                dt.push((yf[t], 1.0));
            }
            if t > 0 {
                at.push((yf[t - 1], 1.0));
                dt.push((yf[t - 1], -1.0));
            }
            m.constrain(at, Cmp::Ge, 0.0);
            m.constrain(dt, Cmp::Ge, 0.0);
            avars.push(a);
        }
        // Persistence: allocations made in the last S intervals must still
        // be allocated: Y_{t+S} >= Σ_{τ=t..t+S} A_τ (A_τ := alloc at the
        // start of interval τ). Only meaningful for S > 1.
        if s_intervals > 1 {
            let s = s_intervals;
            for t in 0..t_len.saturating_sub(s) {
                // Window of alloc steps [t+1 ..= t+s] leading into Y_{t+s}.
                let mut terms = vec![(yf[t + s], 1.0)];
                for tau in (t + 1)..=(t + s) {
                    terms.push((avars[tau], -1.0));
                }
                m.constrain(terms, Cmp::Ge, 0.0);
            }
        }
        m
    }
}

impl RateTrace {
    /// Rebin tolerantly for fluid instances: pads the tail slot.
    pub fn rebin_to(&self, new_dt: f64) -> RateTrace {
        if (new_dt - self.dt).abs() < 1e-9 {
            return self.clone();
        }
        let k = (new_dt / self.dt).round().max(1.0) as usize;
        let rates = self
            .rates
            .chunks(k)
            .map(|c| c.iter().sum::<f64>() / k as f64)
            .collect();
        RateTrace {
            dt: new_dt,
            rates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(demand: Vec<f64>) -> FluidInstance {
        FluidInstance {
            demand_f: demand,
            interval: 10.0,
            platform: PlatformConfig::paper_default(),
        }
    }

    #[test]
    fn from_rates_converts_to_fpga_equivalents() {
        // 10k req/s of 10ms requests at 2x: one FPGA absorbs 200 req/s →
        // 50 FPGA-equivalents.
        let rates = RateTrace::new(1.0, vec![10_000.0; 20]);
        let f = FluidInstance::from_rates(&rates, 0.010, 10.0, PlatformConfig::paper_default());
        assert_eq!(f.demand_f.len(), 2);
        assert!((f.demand_f[0] - 50.0).abs() < 1e-9);
    }

    #[test]
    fn stage_costs_split_busy_idle_cpu() {
        let f = inst(vec![1.5]);
        // y=2: 1.5 busy, 0.5 idle, no CPUs.
        let (e, c) = f.stage(2, 1.5, PlatformMode::Hybrid);
        assert!((e - (1.5 * 50.0 + 0.5 * 20.0) * 10.0).abs() < 1e-9);
        assert!((c - 2.0 * 0.982 / 3600.0 * 10.0).abs() < 1e-12);
        // y=1: 1 busy FPGA + 0.5 FPGA-equiv on CPUs (1 CPU worker).
        let (e, _) = f.stage(1, 1.5, PlatformMode::Hybrid);
        assert!((e - (1.0 * 50.0 + 1.0 * 150.0) * 10.0).abs() < 1e-9);
    }

    #[test]
    fn transition_energy() {
        let f = inst(vec![1.0]);
        let (e_up, _) = f.transition(0, 2);
        assert!((e_up - 1000.0).abs() < 1e-9); // 2 x 500 J
        let (e_down, _) = f.transition(2, 1);
        assert!((e_down - 5.0).abs() < 1e-9); // 0.1s x 50 W
    }

    #[test]
    fn milp_solves_tiny_hybrid_instance() {
        // Demand 1 FPGA for 3 intervals: energy-optimal = keep 1 FPGA.
        let f = inst(vec![1.0, 1.0, 1.0]);
        let m = f.build_milp(PlatformMode::Hybrid, Objective::energy());
        let s = m.solve(20_000).unwrap();
        // Normalized objective: 3 busy intervals + spin up/down ≈
        // 3 + 500/500 + 5/500.
        let expect = 3.0 + (500.0 + 5.0) / 500.0;
        assert!(
            (s.objective - expect).abs() < 0.05,
            "obj {} vs {expect}",
            s.objective
        );
    }

    #[test]
    fn milp_cpu_only_mode_uses_no_fpgas() {
        let f = inst(vec![0.5, 1.0]);
        let m = f.build_milp(PlatformMode::CpuOnly, Objective::energy());
        let s = m.solve(20_000).unwrap();
        // All on CPUs: energy = d*S*B_c*ts summed = (0.5+1)*2*150*10.
        let expect = (0.5 + 1.0) * 2.0 * 150.0 * 10.0 / (50.0 * 10.0);
        assert!((s.objective - expect).abs() < 1e-3, "obj {}", s.objective);
    }

    #[test]
    fn milp_fpga_only_covers_demand() {
        let f = inst(vec![0.2]);
        let m = f.build_milp(PlatformMode::FpgaOnly, Objective::cost());
        let s = m.solve(20_000).unwrap();
        // Must allocate 1 FPGA even for 0.2 demand: cost = 1 interval.
        assert!((s.objective - 1.0).abs() < 1e-6, "obj {}", s.objective);
    }
}
