//! Exact §3 solver at per-second granularity with the spin-up
//! persistence constraint (Table 3, last row): once an FPGA is allocated
//! it must remain allocated for at least S intervals (S = spin-up / dt).
//!
//! Decomposition: layer the continuous demand `d_t` into worker *ranks* —
//! rank k is busy for `frac_k(t) = clamp(d_t - (k-1), 0, 1)` of slot `t`.
//! Rank layers are independent (busy counts, idle counts, and alloc steps
//! all decompose by layer for monotone-layered policies, which are WLOG
//! optimal here), so the global optimum is the sum of per-rank optima.
//! Each rank solves a tiny DP over states {Off, On(age 1..=S)}:
//!
//! * On: serve the layer's fraction on the FPGA (busy power), idle power
//!   for the remainder, occupancy cost; age < S forbids turning off.
//! * Off: serve the fraction on burst CPUs (S_f x CPU seconds) — hybrid
//!   mode only.
//!
//! Cross-checks: equals the Table 3 MILP (with persistence rows) on small
//! instances, and equals the interval-granularity trajectory DP when
//! S = 1 (tests in this module and `rust/tests/`).

use super::fluid::{FluidInstance, PlatformMode};
use crate::sched::Objective;

#[derive(Clone, Debug)]
pub struct RankSolveResult {
    pub energy: f64,
    pub cost: f64,
}

impl RankSolveResult {
    pub fn energy_efficiency(&self, inst: &FluidInstance) -> f64 {
        inst.ideal_energy() / self.energy
    }
    pub fn relative_cost(&self, inst: &FluidInstance) -> f64 {
        self.cost / inst.ideal_cost()
    }
}

/// Solve with persistence horizon `s_intervals` (= ceil(spin_up / dt)).
pub fn solve(
    inst: &FluidInstance,
    mode: PlatformMode,
    obj: Objective,
    s_intervals: usize,
) -> RankSolveResult {
    let p = &inst.platform;
    let dt = inst.interval;
    let t_len = inst.demand_f.len();
    let s = s_intervals.max(1);

    // Normalization (same units as Objective::score).
    let e_unit = p.fpga.busy_power * dt;
    let c_unit = p.fpga.cost_per_sec() * dt;
    let score =
        |e: f64, c: f64| obj.w_energy * e / e_unit + obj.w_cost * c / c_unit;

    // Per-slot primitive (energy, cost) for a layer fraction f in [0,1]:
    let on_slot = |f: f64| {
        (
            (f * p.fpga.busy_power + (1.0 - f) * p.fpga.idle_power) * dt,
            p.fpga.cost_per_sec() * dt,
        )
    };
    let off_slot = |f: f64| {
        let cpu_secs = f * p.fpga.speedup * dt;
        (cpu_secs * p.cpu.busy_power, cpu_secs * p.cpu.cost_per_sec())
    };
    let alloc = (p.fpga.spin_up_energy(), 0.0);
    let dealloc = (p.fpga.spin_down_energy(), 0.0);

    if mode == PlatformMode::CpuOnly {
        // Closed form: everything on CPUs.
        let (mut e, mut c) = (0.0, 0.0);
        for &d in &inst.demand_f {
            let cpu_secs = d * p.fpga.speedup * dt;
            e += cpu_secs * p.cpu.busy_power;
            c += cpu_secs * p.cpu.cost_per_sec();
        }
        return RankSolveResult { energy: e, cost: c };
    }

    let peak = inst.demand_f.iter().fold(0.0f64, |a, &b| a.max(b));
    let ranks = peak.ceil() as usize;

    let mut total_e = 0.0;
    let mut total_c = 0.0;

    // DP state encoding: 0 = Off, a in 1..=s = On with age a (s = "mature").
    let n_states = s + 1;
    let mut v = vec![f64::INFINITY; n_states];
    let mut nv = vec![f64::INFINITY; n_states];
    // Backtracking storage: choice[t][state] = predecessor state.
    let mut choice = vec![vec![0u8; n_states]; t_len];

    for k in 1..=ranks {
        let fracs: Vec<f64> = inst
            .demand_f
            .iter()
            .map(|&d| (d - (k - 1) as f64).clamp(0.0, 1.0))
            .collect();
        // DP forward.
        v.fill(f64::INFINITY);
        v[0] = 0.0;
        for (t, &f) in fracs.iter().enumerate() {
            nv.fill(f64::INFINITY);
            let ch = &mut choice[t];
            let (oe, oc) = on_slot(f);
            let on_cost = score(oe, oc);
            let (fe, fc) = off_slot(f);
            let off_cost = if mode == PlatformMode::FpgaOnly && f > 1e-12 {
                f64::INFINITY
            } else {
                score(fe, fc)
            };
            let (ae, ac) = alloc;
            let (de, dc) = dealloc;
            // Off -> Off.
            if v[0] + off_cost < nv[0] {
                nv[0] = v[0] + off_cost;
                ch[0] = 0;
            }
            // Mature On -> Off (dealloc then serve off).
            let cand = v[s] + score(de, dc) + off_cost;
            if cand < nv[0] {
                nv[0] = cand;
                ch[0] = s as u8;
            }
            // Off -> On(1) (alloc).
            let cand = v[0] + score(ae, ac) + on_cost;
            if cand < nv[1.min(s)] {
                nv[1.min(s)] = cand;
                ch[1.min(s)] = 0;
            }
            // On(a) -> On(min(a+1, s)).
            for a in 1..=s {
                let next = (a + 1).min(s);
                let cand = v[a] + on_cost;
                if cand < nv[next] {
                    nv[next] = cand;
                    ch[next] = a as u8;
                }
            }
            std::mem::swap(&mut v, &mut nv);
        }
        // Terminal: pay dealloc if still on.
        let (de, dc) = dealloc;
        let mut best = (v[0], 0usize);
        for a in 1..=s {
            let cand = v[a] + score(de, dc);
            if cand < best.0 {
                best = (cand, a);
            }
        }
        if !best.0.is_finite() {
            debug_assert!(false, "rank {k} infeasible");
            continue;
        }
        // Backtrack to re-accumulate exact energy/cost (unnormalized).
        let mut state = best.1;
        let mut states_rev = Vec::with_capacity(t_len);
        for t in (0..t_len).rev() {
            states_rev.push(state);
            state = choice[t][state] as usize;
        }
        states_rev.reverse();
        let (mut e, mut c) = (0.0, 0.0);
        if best.1 != 0 {
            e += de;
            c += dc;
        }
        let mut prev = 0usize;
        for (t, &st) in states_rev.iter().enumerate() {
            let f = fracs[t];
            if st == 0 {
                if prev != 0 {
                    e += de;
                    c += dc;
                }
                let (fe, fc) = off_slot(f);
                e += fe;
                c += fc;
            } else {
                if prev == 0 {
                    let (ae, ac) = alloc;
                    e += ae;
                    c += ac;
                }
                let (oe, oc) = on_slot(f);
                e += oe;
                c += oc;
            }
            prev = st;
        }
        total_e += e;
        total_c += c;
    }

    RankSolveResult {
        energy: total_e,
        cost: total_c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn inst(demand: Vec<f64>, dt: f64) -> FluidInstance {
        FluidInstance {
            demand_f: demand,
            interval: dt,
            platform: PlatformConfig::paper_default(),
        }
    }

    #[test]
    fn matches_trajectory_dp_when_s_is_one() {
        use crate::opt::dp;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        for _ in 0..5 {
            let demand: Vec<f64> = (0..30).map(|_| rng.below(4) as f64).collect();
            let f = inst(demand, 10.0);
            for (mode, obj) in [
                (PlatformMode::Hybrid, Objective::energy()),
                (PlatformMode::Hybrid, Objective::cost()),
                (PlatformMode::FpgaOnly, Objective::energy()),
            ] {
                let a = solve(&f, mode, obj, 1);
                let b = dp::solve(&f, mode, obj);
                let e_unit = 500.0;
                let c_unit = 0.982 / 360.0;
                let sa = obj.w_energy * a.energy / e_unit + obj.w_cost * a.cost / c_unit;
                let sb = obj.w_energy * b.energy / e_unit + obj.w_cost * b.cost / c_unit;
                assert!(
                    (sa - sb).abs() < 1e-6 * (1.0 + sb.abs()),
                    "{mode:?} {obj:?}: rank {sa} vs dp {sb}"
                );
            }
        }
    }

    #[test]
    fn persistence_forces_idle_commitment() {
        // Demand blips for one second; with S=10 the FPGA must stay
        // allocated 10 slots → cost includes 10 slots of occupancy.
        let mut d = vec![1.0];
        d.extend(vec![0.0; 20]);
        let f = inst(d, 1.0);
        let r = solve(&f, PlatformMode::FpgaOnly, Objective::cost(), 10);
        let min_occupancy = 10.0 * 0.982 / 3600.0;
        assert!(
            r.cost >= min_occupancy - 1e-9,
            "cost {} must cover 10 slots {min_occupancy}",
            r.cost
        );
    }

    #[test]
    fn hybrid_uses_cpu_for_rare_blips_under_persistence() {
        // A single 1-slot blip: CPU service (2 CPU-s: 0.083 J-normalized)
        // beats alloc 500 J + 10-slot commitment.
        let mut d = vec![0.0; 5];
        d.push(1.0);
        d.extend(vec![0.0; 15]);
        let f = inst(d, 1.0);
        let r = solve(&f, PlatformMode::Hybrid, Objective::energy(), 10);
        // Pure CPU for the blip: 1 fpga-equiv x 2 x 150 W x 1 s = 300 J.
        assert!((r.energy - 300.0).abs() < 1e-9, "energy {}", r.energy);
    }

    #[test]
    fn steady_high_demand_prefers_fpgas() {
        let f = inst(vec![2.0; 60], 1.0);
        let r = solve(&f, PlatformMode::Hybrid, Objective::energy(), 10);
        // 2 FPGAs busy for 60 s + alloc/dealloc pairs.
        let expect = 2.0 * 50.0 * 60.0 + 2.0 * 505.0;
        assert!((r.energy - expect).abs() < 1e-6, "energy {}", r.energy);
    }

    #[test]
    fn matches_milp_with_persistence_on_small_instances() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        for case in 0..4 {
            let t = 6;
            let demand: Vec<f64> = (0..t).map(|_| rng.below(3) as f64).collect();
            let f = inst(demand.clone(), 1.0);
            let s = 2usize;
            let milp = f
                .build_milp_persist(PlatformMode::Hybrid, Objective::energy(), s)
                .solve(400_000);
            let milp = match milp {
                Ok(m) => m,
                Err(e) => panic!("milp failed on {demand:?}: {e:?}"),
            };
            let rank = solve(&f, PlatformMode::Hybrid, Objective::energy(), s);
            let e_unit = 50.0 * 1.0;
            let rank_score = rank.energy / e_unit;
            assert!(
                (rank_score - milp.objective).abs() < 1e-3 * (1.0 + milp.objective),
                "case {case} {demand:?}: rank {rank_score} vs milp {}",
                milp.objective
            );
        }
    }
}
