//! Foundation substrates built in-repo (the offline registry only ships
//! `xla` + `anyhow`): PRNG, statistics, JSON, table rendering, and a
//! property-testing harness.

pub mod executor;
pub mod json;
pub mod ordf64;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
