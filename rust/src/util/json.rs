//! Minimal JSON parser and writer.
//!
//! serde is not available in the offline registry, so config files, trace
//! files, and result dumps go through this hand-rolled implementation. It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) and preserves object key order.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Field lookup returning f64, falling back to `default` when absent.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_map(m: &BTreeMap<String, f64>) -> Json {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !kvs.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Note: surrogate pairs unsupported (not needed
                            // for our ASCII-only config/result files).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"spork","weights":[0.5,0.5],"ideal":false,"n":3}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn round_trip_escapes() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn accessors_and_defaults() {
        let v = Json::parse(r#"{"x": 2.5, "s": "str", "b": true}"#).unwrap();
        assert_eq!(v.f64_or("x", 0.0), 2.5);
        assert_eq!(v.f64_or("missing", 7.0), 7.0);
        assert_eq!(v.str_or("s", "d"), "str");
        assert_eq!(v.str_or("missing", "d"), "d");
        assert!(v.bool_or("b", false));
        assert_eq!(Json::parse("3").unwrap().as_u64(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_u64(), None);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("b", Json::obj(vec![("c", Json::Str("d".into()))])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }
}
