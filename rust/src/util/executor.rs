//! Process-wide bounded executor: one concurrency budget for every
//! intra-run fan-out (DESIGN.md §14).
//!
//! The experiment harness parallelizes at three nesting levels — sweep
//! cells across a grid, apps inside a production cell, and candidate
//! drivers inside a lockstep fitting batch. Giving each level its own
//! `--jobs` worth of threads would oversubscribe multiplicatively
//! (jobs³ live threads in the worst nest). Instead a single
//! [`Executor`] holds the budget as a pool of *extra-worker permits*:
//!
//! - A fan-out's calling thread always participates in its own work —
//!   it holds an implicit permit by virtue of running. Only the
//!   *additional* scoped workers it wants must be acquired from the
//!   shared pool, so a budget of `B` funds `B - 1` extra permits and
//!   the number of threads executing work is never more than `B`, no
//!   matter how fan-outs nest.
//! - Acquisition is best-effort and non-blocking: a fan-out takes
//!   whatever is available up to its cap and runs with that. Zero
//!   available means the fan-out degrades to a plain inline loop on the
//!   calling thread — graceful degradation, never a deadlock, and the
//!   innermost levels of a saturated nest simply run serial.
//! - Results are placed by item index, so the output (and every
//!   floating-point merge the caller folds over it in index order) is
//!   bit-identical for any budget. *Scheduling* order is not
//!   deterministic; result *placement* is.
//!
//! A worker panic is caught per item and re-raised on the calling
//! thread with the failing item index attached (lowest index wins when
//! several workers trip), so grid failures are attributable instead of
//! surfacing as an opaque scope abort.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Resolve a `--jobs` value: `0` means auto (one worker per core).
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        jobs
    }
}

/// Best-effort human-readable text of a caught panic payload.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

static GLOBAL: OnceLock<Executor> = OnceLock::new();

/// A bounded pool of extra-worker permits shared by every fan-out in
/// the process (see the module doc for the permit model).
pub struct Executor {
    /// Extra permits currently available (`budget - 1` when idle —
    /// the caller thread of any fan-out is the implicit first worker).
    extra: AtomicUsize,
    budget: usize,
}

impl Executor {
    /// Executor with a budget of `effective_jobs(jobs)` concurrent
    /// threads (so `jobs == 0` means one per core, `jobs == 1` means
    /// everything inline).
    pub fn new(jobs: usize) -> Self {
        let budget = effective_jobs(jobs);
        Executor {
            extra: AtomicUsize::new(budget.saturating_sub(1)),
            budget,
        }
    }

    /// The process-wide executor. First use wins: call
    /// [`Executor::configure`] from the CLI entry point before any
    /// fan-out runs; a plain `global()` without prior configuration
    /// initializes at the auto budget (one thread per core).
    pub fn global() -> &'static Executor {
        GLOBAL.get_or_init(|| Executor::new(0))
    }

    /// Seed the global executor from `--jobs`. Idempotent for equal
    /// budgets; a conflicting later configuration is ignored with a
    /// warning (the budget is process-wide state — permits may already
    /// be on loan, so it cannot be resized in flight).
    pub fn configure(jobs: usize) {
        let budget = effective_jobs(jobs);
        let exec = GLOBAL.get_or_init(|| Executor::new(jobs));
        if exec.budget != budget {
            eprintln!(
                "warning: executor already holds a budget of {} threads; ignoring --jobs {jobs}",
                exec.budget
            );
        }
    }

    /// Total concurrency budget (threads, counting the caller).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Extra permits currently unclaimed (`budget() - 1` when no
    /// fan-out is in flight). Test/diagnostic accessor.
    pub fn available(&self) -> usize {
        self.extra.load(Ordering::Relaxed)
    }

    /// Claim up to `want` extra permits, non-blocking: takes
    /// `min(want, available)`, possibly zero. Released on drop.
    pub fn acquire(&self, want: usize) -> Permits<'_> {
        if want == 0 {
            return Permits { exec: self, n: 0 };
        }
        let mut cur = self.extra.load(Ordering::Relaxed);
        loop {
            let take = cur.min(want);
            if take == 0 {
                return Permits { exec: self, n: 0 };
            }
            match self.extra.compare_exchange_weak(
                cur,
                cur - take,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Permits { exec: self, n: take },
                Err(now) => cur = now,
            }
        }
    }

    fn release(&self, n: usize) {
        if n > 0 {
            self.extra.fetch_add(n, Ordering::Release);
        }
    }

    /// Order-preserving bounded parallel map: applies `f` to every item
    /// across the calling thread plus up to `cap - 1` permit-backed
    /// scoped workers (work-stealing over an atomic cursor) and returns
    /// results in item order. `cap == 0` means "as many as the budget
    /// allows". `f(i, item)` must depend only on its arguments for the
    /// output to be deterministic. Degrades to an inline serial loop
    /// when the items, the cap, or the permit pool don't support
    /// parallelism — same results either way.
    pub fn map<T, R, F>(&self, items: &[T], cap: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = items.len();
        if n > 1 {
            let cap_extra = if cap == 0 {
                n - 1
            } else {
                cap.saturating_sub(1).min(n - 1)
            };
            if cap_extra > 0 {
                let permits = self.acquire(cap_extra);
                if permits.count() > 0 {
                    return run_scoped(items, permits, &f);
                }
            }
        }
        items.iter().enumerate().map(|(i, t)| f(i, t)).collect()
    }

    /// Like [`Executor::map`], but refuses to run *without* real
    /// parallelism: returns `None` (touching no item) when fewer than
    /// two items were given or no extra permit is available, so the
    /// caller can choose a different serial plan instead of an inline
    /// loop (the lockstep fitting batch falls back to its shared-tee
    /// pass — see `sched::fit`).
    pub fn try_map<T, R, F>(&self, items: &[T], f: F) -> Option<Vec<R>>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        if items.len() <= 1 {
            return None;
        }
        let permits = self.acquire(items.len() - 1);
        if permits.count() == 0 {
            return None;
        }
        Some(run_scoped(items, permits, &f))
    }
}

/// Extra-worker permits on loan from an [`Executor`]; returned to the
/// pool on drop.
pub struct Permits<'a> {
    exec: &'a Executor,
    n: usize,
}

impl Permits<'_> {
    pub fn count(&self) -> usize {
        self.n
    }
}

impl Drop for Permits<'_> {
    fn drop(&mut self) {
        self.exec.release(self.n);
    }
}

/// The scoped work-stealing loop behind [`Executor::map`] /
/// [`Executor::try_map`]: `permits.count()` spawned workers plus the
/// calling thread race over an atomic cursor; each item runs under
/// `catch_unwind` so a panic stops the fan-out early (cooperative
/// abort flag) and is re-raised on the calling thread with the item
/// index attached. Permits are released when this returns *or*
/// unwinds (drop-guard).
fn run_scoped<T, R, F>(items: &[T], permits: Permits<'_>, f: &F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    type Caught = Box<dyn Any + Send>;
    let n = items.len();
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let work = || {
        let mut ok: Vec<(usize, R)> = Vec::new();
        let mut caught: Option<(usize, Caught)> = None;
        loop {
            if abort.load(Ordering::Relaxed) {
                break;
            }
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            match catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))) {
                Ok(r) => ok.push((i, r)),
                Err(payload) => {
                    abort.store(true, Ordering::Relaxed);
                    caught = Some((i, payload));
                    break;
                }
            }
        }
        (ok, caught)
    };
    let mut parts: Vec<(Vec<(usize, R)>, Option<(usize, Caught)>)> =
        Vec::with_capacity(permits.count() + 1);
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..permits.count())
            .map(|_| scope.spawn(&work))
            .collect();
        parts.push(work());
        for w in workers {
            parts.push(w.join().expect("executor worker died outside catch_unwind"));
        }
    });
    drop(permits);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut first_panic: Option<(usize, Caught)> = None;
    for (ok, caught) in parts {
        for (i, r) in ok {
            debug_assert!(slots[i].is_none(), "duplicate parallel map result for {i}");
            slots[i] = Some(r);
        }
        if let Some((i, payload)) = caught {
            match &first_panic {
                Some((j, _)) if *j <= i => {}
                _ => first_panic = Some((i, payload)),
            }
        }
    }
    if let Some((i, payload)) = first_panic {
        panic!(
            "parallel map: worker panicked at item {i}: {}",
            panic_message(payload.as_ref())
        );
    }
    slots
        .into_iter()
        .map(|r| r.expect("missing parallel map result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn map_preserves_order_and_coverage() {
        let exec = Executor::new(4);
        let items: Vec<u64> = (0..257).collect();
        for cap in [0, 1, 2, 7] {
            let out = exec.map(&items, cap, |i, &x| {
                assert_eq!(i as u64, x);
                x * 3 + 1
            });
            assert_eq!(out.len(), items.len());
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as u64 * 3 + 1, "cap={cap}");
            }
            assert_eq!(exec.available(), 3, "permits leaked at cap={cap}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let exec = Executor::new(4);
        let out: Vec<u32> = exec.map(&[], 0, |_, x: &u32| *x);
        assert!(out.is_empty());
        let out = exec.map(&[9u32], 0, |_, x| x + 1);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn effective_jobs_resolves_auto() {
        assert!(effective_jobs(0) >= 1);
        assert_eq!(effective_jobs(3), 3);
    }

    /// Live threads executing work never exceed the budget, including
    /// when fan-outs nest on the same executor: the outer map's workers
    /// consume permits, so inner maps find fewer (or none) and degrade.
    #[test]
    fn live_threads_never_exceed_budget() {
        let exec = Executor::new(3);
        let live = AtomicUsize::new(0);
        let high = AtomicUsize::new(0);
        let outer: Vec<u32> = (0..6).collect();
        let inner: Vec<u32> = (0..8).collect();
        let enter = || {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            high.fetch_max(now, Ordering::SeqCst);
        };
        let exit = || {
            live.fetch_sub(1, Ordering::SeqCst);
        };
        let sums = exec.map(&outer, 0, |_, &o| {
            enter();
            let part = exec.map(&inner, 0, |_, &x| {
                enter();
                std::thread::sleep(Duration::from_millis(1));
                exit();
                o as u64 * 100 + x as u64
            });
            exit();
            part.iter().sum::<u64>()
        });
        // Each worker counts itself once at the outer level and once per
        // inner item, so the high-water mark counts *stacked* frames on
        // one thread twice; bound by 2x budget for the nest, and the
        // inner-only bound (threads actually running f) is the budget.
        assert!(
            high.load(Ordering::SeqCst) <= 2 * exec.budget(),
            "high-water {} exceeds nest bound {}",
            high.load(Ordering::SeqCst),
            2 * exec.budget()
        );
        for (o, s) in sums.iter().enumerate() {
            let expect: u64 = (0..8).map(|x| o as u64 * 100 + x).sum();
            assert_eq!(*s, expect);
        }
        assert_eq!(exec.available(), 2, "permits leaked after nested maps");
    }

    /// The flat (non-nested) thread bound is exact: at most `budget`
    /// threads ever run `f` concurrently.
    #[test]
    fn flat_fanout_respects_budget_exactly() {
        let exec = Executor::new(3);
        let live = AtomicUsize::new(0);
        let high = AtomicUsize::new(0);
        let items: Vec<u32> = (0..32).collect();
        exec.map(&items, 0, |_, &x| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            high.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(1));
            live.fetch_sub(1, Ordering::SeqCst);
            x
        });
        assert!(
            high.load(Ordering::SeqCst) <= exec.budget(),
            "high-water {} exceeds budget {}",
            high.load(Ordering::SeqCst),
            exec.budget()
        );
    }

    #[test]
    fn budget_one_runs_inline() {
        let exec = Executor::new(1);
        assert_eq!(exec.available(), 0);
        let caller = std::thread::current().id();
        let items: Vec<u32> = (0..16).collect();
        let out = exec.map(&items, 0, |_, &x| {
            assert_eq!(std::thread::current().id(), caller);
            x + 1
        });
        assert_eq!(out.len(), 16);
    }

    #[test]
    fn try_map_declines_without_parallelism() {
        let serial = Executor::new(1);
        let items: Vec<u32> = (0..4).collect();
        assert!(serial.try_map(&items, |_, &x| x).is_none());
        let par = Executor::new(4);
        assert!(par.try_map(&items[..1], |_, &x| x).is_none());
        let out = par.try_map(&items, |_, &x| x * 2).expect("permits exist");
        assert_eq!(out, vec![0, 2, 4, 6]);
        assert_eq!(par.available(), 3);
    }

    #[test]
    fn worker_panic_reraises_with_item_index() {
        let exec = Executor::new(4);
        let items: Vec<u32> = (0..64).collect();
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.map(&items, 0, |i, &x| {
                if i == 5 {
                    panic!("boom at {x}");
                }
                x
            })
        }))
        .expect_err("must panic");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("item 5"), "missing index: {msg}");
        assert!(msg.contains("boom at 5"), "missing payload: {msg}");
        assert_eq!(exec.available(), 3, "permits leaked after panic");
    }

    #[test]
    fn permits_acquire_release_roundtrip() {
        let exec = Executor::new(4);
        let p = exec.acquire(2);
        assert_eq!(p.count(), 2);
        assert_eq!(exec.available(), 1);
        let q = exec.acquire(5);
        assert_eq!(q.count(), 1, "acquire is capped by availability");
        assert_eq!(exec.available(), 0);
        let r = exec.acquire(1);
        assert_eq!(r.count(), 0, "empty pool yields zero, never blocks");
        drop(q);
        drop(p);
        drop(r);
        assert_eq!(exec.available(), 3);
    }
}
