//! Deterministic, seedable pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we implement the two PRNGs
//! this project needs from scratch:
//!
//! * [`SplitMix64`] — used only to expand a user seed into xoshiro state
//!   (the construction recommended by the xoshiro authors).
//! * [`Xoshiro256pp`] — the general-purpose generator used by every
//!   stochastic component (trace generation, property tests, jitter).
//!
//! All simulation randomness must flow through [`Rng`] so experiment runs are
//! exactly reproducible from a `u64` seed.

/// SplitMix64: a tiny, high-quality 64-bit mixer (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ (Blackman & Vigna): fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }
}

/// The project-wide RNG handle. Wraps xoshiro256++ with the distribution
/// helpers the simulator and trace generators need.
#[derive(Clone, Debug)]
pub struct Rng {
    inner: Xoshiro256pp,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self {
            inner: Xoshiro256pp::seed_from_u64(seed),
        }
    }

    /// Derive an independent child stream (for per-app / per-run streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        // Mix the stream id through splitmix so sibling forks decorrelate.
        let mut sm = SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        Rng {
            inner: Xoshiro256pp::seed_from_u64(sm.next_u64()),
        }
    }

    /// Derive an independent stream as a *pure function* of `(seed,
    /// stream)` — unlike [`Rng::fork`], no parent generator is consumed,
    /// so the result does not depend on how many streams were split
    /// before it or on which thread asks. This is the construction the
    /// parallel sweep engine uses to give every (scheduler, workload,
    /// seed) cell its own generator while keeping results bit-identical
    /// for any `--jobs` value.
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        // Two splitmix rounds: decorrelate the seed, then fold in the
        // stream id with a golden-ratio spread (as `fork` does).
        let mut sm = SplitMix64::new(seed);
        let base = sm.next_u64();
        let mut sm = SplitMix64::new(base ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        Rng {
            inner: Xoshiro256pp::seed_from_u64(sm.next_u64()),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.inner.next_u64()
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with given rate (mean 1/rate). Used for Poisson
    /// interarrival gaps.
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // Avoid ln(0): f64() is in [0,1), so 1-f64() is in (0,1].
        -(1.0 - self.f64()).ln() / rate
    }

    /// Poisson(lambda) via inversion for small lambda and normal
    /// approximation (with continuity correction, clamped at 0) for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            // Knuth inversion.
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation N(lambda, lambda).
            let z = self.normal(0.0, 1.0);
            let v = lambda + lambda.sqrt() * z + 0.5;
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Normal(mu, sigma) via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        let u1 = 1.0 - self.f64(); // (0,1]
        let u2 = self.f64();
        let mag = (-2.0 * u1.ln()).sqrt();
        mu + sigma * mag * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal such that the *median* is `median` and the underlying
    /// normal has std `sigma` (in log space). Used for request-size draws.
    pub fn lognormal_median(&mut self, median: f64, sigma: f64) -> f64 {
        (self.normal(median.ln(), sigma)).exp()
    }

    /// Pareto (power-law) with scale xm and shape alpha. Used to synthesize
    /// the heavy-demand skew of the production traces.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm / (1.0 - self.f64()).powf(1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_small_lambda_mean_var() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.poisson(4.0) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::new(8);
        let n = 20_000;
        let mean = (0..n).map(|_| r.poisson(500.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 500.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn for_stream_is_pure_and_order_independent() {
        // Same (seed, stream) → identical generator, regardless of what
        // else was derived before.
        let mut a = Rng::for_stream(42, 3);
        let _ = Rng::for_stream(42, 999); // unrelated derivation
        let mut b = Rng::for_stream(42, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn for_stream_decorrelates_streams_and_seeds() {
        let mut a = Rng::for_stream(7, 0);
        let mut b = Rng::for_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "sibling streams correlated ({same} matches)");
        let mut c = Rng::for_stream(7, 0);
        let mut d = Rng::for_stream(8, 0);
        let same = (0..64).filter(|_| c.next_u64() == d.next_u64()).count();
        assert!(same < 2, "adjacent seeds correlated ({same} matches)");
    }

    #[test]
    fn forks_decorrelate() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn pareto_bounded_below() {
        let mut r = Rng::new(12);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }
}
