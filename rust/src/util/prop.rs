//! Miniature property-based testing harness (proptest is not available in
//! the offline registry).
//!
//! Usage pattern, mirroring proptest's `proptest!` loop:
//!
//! ```ignore
//! prop_check(100, |rng| {
//!     let xs = gen_vec(rng, 0..50, |r| r.range_f64(0.0, 10.0));
//!     let prop = my_invariant(&xs);
//!     PropResult::assert(prop, format!("violated for {xs:?}"))
//! });
//! ```
//!
//! Each case runs with a distinct deterministic seed; on failure the harness
//! reports the failing seed so the case can be replayed, and re-runs a few
//! "shrunk" attempts by re-generating with smaller size hints.

use super::rng::Rng;

pub struct PropResult {
    pub ok: bool,
    pub msg: String,
}

impl PropResult {
    pub fn pass() -> Self {
        Self {
            ok: true,
            msg: String::new(),
        }
    }

    pub fn assert(cond: bool, msg: impl Into<String>) -> Self {
        Self {
            ok: cond,
            msg: if cond { String::new() } else { msg.into() },
        }
    }

    pub fn approx_eq(a: f64, b: f64, tol: f64, ctx: &str) -> Self {
        let ok = (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()));
        Self {
            ok,
            msg: if ok {
                String::new()
            } else {
                format!("{ctx}: {a} != {b} (tol {tol})")
            },
        }
    }

    pub fn and(self, other: PropResult) -> PropResult {
        if self.ok {
            other
        } else {
            self
        }
    }
}

/// Context handed to each property case: RNG plus a size hint in [0,1] that
/// grows over the run (small cases first — a poor man's shrinking).
pub struct Case {
    pub rng: Rng,
    pub size: f64,
    pub seed: u64,
}

impl Case {
    /// Scaled length: lengths grow with the size hint so early cases are
    /// small and easy to debug when they fail.
    pub fn len(&mut self, max: usize) -> usize {
        let cap = ((max as f64) * self.size).ceil().max(1.0) as usize;
        self.rng.below(cap as u64 + 1) as usize
    }

    pub fn vec_f64(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    pub fn vec_u64(&mut self, max_len: usize, lo: u64, hi: u64) -> Vec<u64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.rng.range_u64(lo, hi)).collect()
    }
}

/// Run `cases` property cases; panics with the failing seed on first failure.
pub fn prop_check<F>(cases: u64, mut f: F)
where
    F: FnMut(&mut Case) -> PropResult,
{
    // Base seed can be overridden for replay via SPORK_PROP_SEED.
    let base = std::env::var("SPORK_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut case = Case {
            rng: Rng::new(seed),
            size: ((i + 1) as f64 / cases as f64).min(1.0),
            seed,
        };
        let r = f(&mut case);
        if !r.ok {
            panic!(
                "property failed on case {i} (seed {seed}; replay with SPORK_PROP_SEED={base}): {}",
                r.msg
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(50, |c| {
            count += 1;
            let v = c.vec_f64(20, -1.0, 1.0);
            PropResult::assert(v.iter().all(|x| x.abs() <= 1.0), "out of range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(50, |c| {
            let v = c.vec_u64(30, 0, 100);
            PropResult::assert(v.len() < 10, format!("len={}", v.len()))
        });
    }

    #[test]
    fn sizes_grow() {
        let mut first_len = None;
        let mut max_len = 0;
        prop_check(100, |c| {
            let l = c.len(1000);
            if first_len.is_none() {
                first_len = Some(l);
            }
            max_len = max_len.max(l);
            PropResult::pass()
        });
        assert!(first_len.unwrap() <= 10, "early cases should be small");
        assert!(max_len > 100, "late cases should be large");
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(PropResult::approx_eq(1.0, 1.0 + 1e-12, 1e-9, "x").ok);
        assert!(!PropResult::approx_eq(1.0, 1.1, 1e-9, "x").ok);
    }
}

/// Pool-index coherence: the five ordered indexes (live / idle / ready /
/// busy / spinup) must stay in sync with the slab through arbitrary
/// [`crate::sim::pool::Pool::with_mut`] transitions, and the extremal
/// dispatch queries must match brute-force scans — including the
/// lowest-id tie-break, which the quantized value grid here exercises
/// hard (many equal keys).
#[cfg(test)]
mod pool_index_props {
    use super::*;
    use crate::config::WorkerKind;
    use crate::sim::pool::Pool;
    use crate::sim::{Worker, WorkerId, WorkerState};

    fn scan_busiest_busy(p: &Pool, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        for w in p.iter_kind(kind) {
            if w.state == WorkerState::Active
                && w.queued > 0
                && w.busy_until <= bound
                && best.map_or(true, |(b, _)| w.busy_until > b)
            {
                best = Some((w.busy_until, w.id));
            }
        }
        best
    }

    fn scan_most_recently_idle(p: &Pool, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        for w in p.iter_kind(kind) {
            if w.state == WorkerState::Active
                && w.queued == 0
                && best.map_or(true, |(s, _)| w.idle_since > s)
            {
                best = Some((w.idle_since, w.id));
            }
        }
        best
    }

    fn scan_most_loaded_spinup(p: &Pool, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        for w in p.iter_kind(kind) {
            if w.state == WorkerState::SpinningUp && w.busy_until <= bound {
                let load = w.busy_until - w.ready_at;
                if best.map_or(true, |(l, _)| load > l) {
                    best = Some((load, w.id));
                }
            }
        }
        best
    }

    fn scan_busiest_packed(p: &Pool, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        for w in p.iter_kind(kind) {
            let packed = w.state == WorkerState::SpinningUp
                || (w.state == WorkerState::Active && w.queued > 0);
            if packed && w.busy_until <= bound && best.map_or(true, |(b, _)| w.busy_until > b) {
                best = Some((w.busy_until, w.id));
            }
        }
        best
    }

    fn scan_earliest_ready(p: &Pool, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        for w in p.iter_kind(kind) {
            if w.accepting() && best.map_or(true, |(b, _)| w.busy_until < b) {
                best = Some((w.busy_until, w.id));
            }
        }
        best
    }

    #[test]
    fn pool_indexes_stay_coherent_under_random_transitions() {
        let kinds = WorkerKind::ALL;
        prop_check(60, |case| {
            let mut pool = Pool::new();
            let mut ids: Vec<WorkerId> = Vec::new();
            let steps = 4 + case.len(150);
            for _ in 0..steps {
                // Quantized values → frequent equal keys → the tie-break
                // paths actually run.
                let grid = 0.25 * case.rng.below(8) as f64;
                match case.rng.below(10) {
                    0..=3 => {
                        let kind = *case.rng.choose(&kinds);
                        let spin = 0.25 * (1 + case.rng.below(4)) as f64;
                        ids.push(pool.insert(|id| Worker::new(id, kind, grid, spin, 0)));
                    }
                    4..=8 if !ids.is_empty() => {
                        let id = *case.rng.choose(&ids);
                        let state = *case.rng.choose(&[
                            WorkerState::SpinningUp,
                            WorkerState::Active,
                            WorkerState::Active,
                            WorkerState::SpinningDown,
                        ]);
                        let queued = case.rng.below(3) as u32;
                        let idle_since = 0.25 * case.rng.below(8) as f64;
                        let load = 0.25 * case.rng.below(4) as f64;
                        pool.with_mut(id, |w| {
                            w.state = state;
                            w.queued = queued;
                            w.ready_at = grid;
                            w.busy_until = grid + load;
                            w.idle_since = idle_since;
                        });
                    }
                    9 if !ids.is_empty() => {
                        let i = case.rng.below(ids.len() as u64) as usize;
                        pool.remove(ids.swap_remove(i));
                    }
                    _ => {}
                }
            }
            pool.check_coherence();
            // Extremal queries must equal the brute-force scans for a
            // spread of feasibility bounds (including one excluding all
            // and one admitting all).
            for &kind in &kinds {
                for bound in [-1.0, 0.5, 1.0, 1.75, 100.0] {
                    let q = (
                        pool.busiest_busy(kind, bound),
                        pool.most_loaded_spinup(kind, bound),
                        pool.busiest_packed(kind, bound),
                    );
                    let s = (
                        scan_busiest_busy(&pool, kind, bound),
                        scan_most_loaded_spinup(&pool, kind, bound),
                        scan_busiest_packed(&pool, kind, bound),
                    );
                    if q != s {
                        return PropResult::assert(
                            false,
                            format!(
                                "indexed != scan for {kind:?} bound {bound}: {q:?} vs {s:?} \
                                 (seed {})",
                                case.seed
                            ),
                        );
                    }
                }
                let idle = (pool.most_recently_idle(kind), pool.earliest_ready(kind));
                let idle_s = (
                    scan_most_recently_idle(&pool, kind),
                    scan_earliest_ready(&pool, kind),
                );
                if idle != idle_s {
                    return PropResult::assert(
                        false,
                        format!(
                            "idle/ready indexed != scan for {kind:?}: {idle:?} vs {idle_s:?} \
                             (seed {})",
                            case.seed
                        ),
                    );
                }
            }
            PropResult::pass()
        });
    }
}

/// Tee fan-out invariant (the property that makes lockstep fitting's
/// abort-dropout bit-identical to serial per-candidate passes): dropping
/// any subset of consumers at any points mid-stream never perturbs what
/// the surviving consumers observe — every survivor sees exactly the
/// full serial stream, in order, bit for bit, and every dropped consumer
/// saw exactly a prefix of it.
#[cfg(test)]
mod tee_props {
    use super::*;
    use crate::trace::{tee, Arrival, TeeSource, VecSource};

    struct Consumer {
        src: TeeSource<'static>,
        got: Vec<Arrival>,
        done: bool,
        drop_after: Option<usize>,
    }

    #[test]
    fn sibling_drops_never_perturb_surviving_consumers() {
        prop_check(40, |case| {
            // Random nondecreasing trace with frequent time ties.
            let n_arr = case.len(120);
            let mut t = 0.0;
            let arrivals: Vec<Arrival> = (0..n_arr)
                .map(|_| {
                    t += 0.25 * case.rng.below(4) as f64;
                    Arrival {
                        time: t,
                        size: 0.001 + case.rng.range_f64(0.0, 0.01),
                    }
                })
                .collect();
            let n = 2 + case.rng.below(4) as usize;
            let src = VecSource::new("prop", arrivals.clone(), t + 1.0);
            let mut consumers: Vec<Option<Consumer>> = tee(Box::new(src), n)
                .into_iter()
                .enumerate()
                .map(|(i, src)| {
                    // ~half the consumers abort at a random pull count;
                    // the last consumer always survives.
                    let drop_after = if i + 1 < n && case.rng.chance(0.5) {
                        Some(case.rng.below(n_arr as u64 + 1) as usize)
                    } else {
                        None
                    };
                    Some(Consumer {
                        src,
                        got: Vec::new(),
                        done: false,
                        drop_after,
                    })
                })
                .collect();
            loop {
                let live: Vec<usize> = (0..n)
                    .filter(|&i| consumers[i].as_ref().is_some_and(|c| !c.done))
                    .collect();
                if live.is_empty() {
                    break;
                }
                let i = live[case.rng.below(live.len() as u64) as usize];
                let c = consumers[i].as_mut().unwrap();
                if c.drop_after == Some(c.got.len()) {
                    // Abort mid-pass: the consumer vanishes (Drop trims
                    // its buffer claim); its prefix must already match.
                    if c.got[..] != arrivals[..c.got.len()] {
                        return PropResult::assert(
                            false,
                            format!("dropped consumer {i} prefix diverged (seed {})", case.seed),
                        );
                    }
                    consumers[i] = None;
                    continue;
                }
                match c.src.next_arrival() {
                    Some(a) => c.got.push(a),
                    None => c.done = true,
                }
            }
            for (i, c) in consumers.into_iter().enumerate() {
                if let Some(c) = c {
                    if c.got != arrivals {
                        return PropResult::assert(
                            false,
                            format!(
                                "surviving consumer {i} diverged from the serial stream \
                                 (seed {})",
                                case.seed
                            ),
                        );
                    }
                }
            }
            PropResult::pass()
        });
    }
}

/// Simulator invariants checked through the prop harness: the worker
/// [`crate::sim::pool::Pool`] must respect the configured `max_cpus` /
/// `max_fpgas` caps for every scheduler, and aggregate energy/cost must
/// be non-negative and monotone in the trace duration (causality: a
/// longer trace is a superset of work, and the engine never un-spends
/// energy or refunds occupancy).
#[cfg(test)]
mod sim_invariant_props {
    use super::*;
    use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
    use crate::sched::run_scheduler;
    use crate::trace::{synthetic_app, AppTrace};

    fn defaults() -> PlatformConfig {
        PlatformConfig::paper_default()
    }

    #[test]
    fn pool_allocation_never_exceeds_caps() {
        prop_check(6, |case| {
            let mut cfg = SimConfig::paper_default();
            let cpu_cap = 1 + case.rng.below(6) as u32;
            let fpga_cap = 1 + case.rng.below(4) as u32;
            cfg.max_cpus = Some(cpu_cap);
            cfg.max_fpgas = Some(fpga_cap);
            let b = case.rng.range_f64(0.55, 0.75);
            let trace = synthetic_app("caps", &mut case.rng, b, 150.0, 250.0, 0.010);
            for kind in [
                SchedulerKind::CpuDynamic,
                SchedulerKind::spork_e(),
                SchedulerKind::MarkIdeal,
            ] {
                let r = run_scheduler(&kind, &trace, &cfg, &defaults());
                let p = PropResult::assert(
                    r.metrics.peak_cpus <= cpu_cap
                        && r.metrics.peak_fpgas <= fpga_cap
                        && r.metrics.requests as usize == trace.len(),
                    format!(
                        "{}: peaks {}/{} vs caps {cpu_cap}/{fpga_cap}, {} of {} requests (seed {})",
                        kind.name(),
                        r.metrics.peak_cpus,
                        r.metrics.peak_fpgas,
                        r.metrics.requests,
                        trace.len(),
                        case.seed
                    ),
                );
                if !p.ok {
                    return p;
                }
            }
            PropResult::pass()
        });
    }

    #[test]
    fn energy_and_cost_nonnegative_and_monotone_in_duration() {
        prop_check(5, |case| {
            let b = case.rng.range_f64(0.5, 0.75);
            let rate = case.rng.range_f64(80.0, 200.0);
            let full = synthetic_app("mono", &mut case.rng, b, 360.0, rate, 0.010);
            let cfg = SimConfig::paper_default();
            // Reactive/causal schedulers only: the oracle-fitted baselines
            // (FPGA-static/dynamic) size fleets from the *whole* trace, so
            // prefix monotonicity is not an invariant for them.
            for kind in [SchedulerKind::CpuDynamic, SchedulerKind::spork_e()] {
                let mut prev = (0.0f64, 0.0f64);
                for &d in &[120.0, 240.0, 360.0] {
                    let prefix = AppTrace::new(
                        "mono",
                        full.arrivals
                            .iter()
                            .copied()
                            .filter(|a| a.time < d)
                            .collect(),
                        d,
                    );
                    let r = run_scheduler(&kind, &prefix, &cfg, &defaults());
                    let e = r.metrics.total_energy();
                    let c = r.metrics.total_cost();
                    let tol_e = 1e-9 * (1.0 + prev.0);
                    let tol_c = 1e-9 * (1.0 + prev.1);
                    let p = PropResult::assert(
                        e >= 0.0 && c >= 0.0 && e + tol_e >= prev.0 && c + tol_c >= prev.1,
                        format!(
                            "{} at d={d}: energy {e} (prev {}), cost {c} (prev {}) (seed {})",
                            kind.name(),
                            prev.0,
                            prev.1,
                            case.seed
                        ),
                    );
                    if !p.ok {
                        return p;
                    }
                    prev = (e, c);
                }
            }
            PropResult::pass()
        });
    }
}
