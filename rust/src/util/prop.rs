//! Miniature property-based testing harness (proptest is not available in
//! the offline registry).
//!
//! Usage pattern, mirroring proptest's `proptest!` loop:
//!
//! ```ignore
//! prop_check(100, |rng| {
//!     let xs = gen_vec(rng, 0..50, |r| r.range_f64(0.0, 10.0));
//!     let prop = my_invariant(&xs);
//!     PropResult::assert(prop, format!("violated for {xs:?}"))
//! });
//! ```
//!
//! Each case runs with a distinct deterministic seed; on failure the harness
//! reports the failing seed so the case can be replayed, and re-runs a few
//! "shrunk" attempts by re-generating with smaller size hints.

use super::rng::Rng;

pub struct PropResult {
    pub ok: bool,
    pub msg: String,
}

impl PropResult {
    pub fn pass() -> Self {
        Self {
            ok: true,
            msg: String::new(),
        }
    }

    pub fn assert(cond: bool, msg: impl Into<String>) -> Self {
        Self {
            ok: cond,
            msg: if cond { String::new() } else { msg.into() },
        }
    }

    pub fn approx_eq(a: f64, b: f64, tol: f64, ctx: &str) -> Self {
        let ok = (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()));
        Self {
            ok,
            msg: if ok {
                String::new()
            } else {
                format!("{ctx}: {a} != {b} (tol {tol})")
            },
        }
    }

    pub fn and(self, other: PropResult) -> PropResult {
        if self.ok {
            other
        } else {
            self
        }
    }
}

/// Context handed to each property case: RNG plus a size hint in [0,1] that
/// grows over the run (small cases first — a poor man's shrinking).
pub struct Case {
    pub rng: Rng,
    pub size: f64,
    pub seed: u64,
}

impl Case {
    /// Scaled length: lengths grow with the size hint so early cases are
    /// small and easy to debug when they fail.
    pub fn len(&mut self, max: usize) -> usize {
        let cap = ((max as f64) * self.size).ceil().max(1.0) as usize;
        self.rng.below(cap as u64 + 1) as usize
    }

    pub fn vec_f64(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    pub fn vec_u64(&mut self, max_len: usize, lo: u64, hi: u64) -> Vec<u64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.rng.range_u64(lo, hi)).collect()
    }
}

/// Run `cases` property cases; panics with the failing seed on first failure.
pub fn prop_check<F>(cases: u64, mut f: F)
where
    F: FnMut(&mut Case) -> PropResult,
{
    // Base seed can be overridden for replay via SPORK_PROP_SEED.
    let base = std::env::var("SPORK_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut case = Case {
            rng: Rng::new(seed),
            size: ((i + 1) as f64 / cases as f64).min(1.0),
            seed,
        };
        let r = f(&mut case);
        if !r.ok {
            panic!(
                "property failed on case {i} (seed {seed}; replay with SPORK_PROP_SEED={base}): {}",
                r.msg
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(50, |c| {
            count += 1;
            let v = c.vec_f64(20, -1.0, 1.0);
            PropResult::assert(v.iter().all(|x| x.abs() <= 1.0), "out of range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(50, |c| {
            let v = c.vec_u64(30, 0, 100);
            PropResult::assert(v.len() < 10, format!("len={}", v.len()))
        });
    }

    #[test]
    fn sizes_grow() {
        let mut first_len = None;
        let mut max_len = 0;
        prop_check(100, |c| {
            let l = c.len(1000);
            if first_len.is_none() {
                first_len = Some(l);
            }
            max_len = max_len.max(l);
            PropResult::pass()
        });
        assert!(first_len.unwrap() <= 10, "early cases should be small");
        assert!(max_len > 100, "late cases should be large");
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(PropResult::approx_eq(1.0, 1.0 + 1e-12, 1e-9, "x").ok);
        assert!(!PropResult::approx_eq(1.0, 1.1, 1e-9, "x").ok);
    }
}
