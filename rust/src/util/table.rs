//! Plain-text table rendering for experiment reports — the harness prints
//! the same rows the paper's tables/figures report, in aligned columns and
//! as CSV for plotting.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned monospace rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<width$}", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &w));
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
        }
        out
    }

    /// CSV rendering (no quoting needed for our numeric/identifier cells,
    /// but commas in cells are escaped defensively).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Markdown rendering for EXPERIMENTS.md.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("**{}**\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format helpers matching the paper's reporting style.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn ratio(x: f64) -> String {
    format!("{:.2}x", x)
}

pub fn sig3(x: f64) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let decimals = (2 - mag).max(0) as usize;
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["Scheduler", "Energy Eff.", "Rel. Cost"]);
        t.row(vec!["SporkE".into(), pct(0.862), ratio(1.34)]);
        t.row(vec!["FPGA-static".into(), pct(0.544), ratio(3.08)]);
        t
    }

    #[test]
    fn render_aligns() {
        let r = sample().render();
        assert!(r.contains("SporkE"));
        assert!(r.contains("86.2%"));
        assert!(r.contains("3.08x"));
        // header and rows start at same column offsets
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[1].starts_with("Scheduler"));
    }

    #[test]
    fn csv_round_fields() {
        let c = sample().to_csv();
        let lines: Vec<&str> = c.lines().collect();
        assert_eq!(lines[0], "Scheduler,Energy Eff.,Rel. Cost");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn markdown_shape() {
        let m = sample().to_markdown();
        assert!(m.contains("| Scheduler | Energy Eff. | Rel. Cost |"));
        assert!(m.contains("|---|---|---|"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sig3_formats() {
        assert_eq!(sig3(1234.0), "1234");
        assert_eq!(sig3(1.2345), "1.23");
        assert_eq!(sig3(0.012345), "0.0123");
    }
}
