//! Summary statistics, percentiles, and streaming accumulators used by the
//! simulator metrics, the Spork predictor, and the experiment harness.

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Exact percentile over a stored sample (fine at our sample sizes; the
/// latency-critical paths use counters, not this).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// The stored values (sorted iff a percentile was taken).
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN in Sample"));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0,100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = rank - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Fixed-bin logarithmic histogram: percentiles with bounded relative
/// error in bounded memory. [`Sample`] stores every value, so a
/// million-request serve replay would hold a million f64s just to report
/// p999; this holds a fixed `Vec<u64>` whose size depends only on the
/// covered range and resolution, never on how many values are added.
///
/// Bins are geometric: bin `i` covers `[lo·g^i, lo·g^(i+1))` where `g` is
/// the per-bin growth factor. A reported percentile is the upper edge of
/// the bin holding the nearest-rank order statistic, clamped to the exact
/// observed `[min, max]`, so its relative error is bounded by `g - 1`
/// for any value in `[lo, hi)`. Values below `lo` land in an underflow
/// bin (reported as at most `lo` — pick `lo` below the resolution you
/// care about); values at or above `hi` land in an overflow bin
/// (reported as the exact observed max).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    lo: f64,
    growth: f64,
    inv_log_growth: f64,
    /// `counts[0]` underflow, `counts[1..=nbins]` geometric bins,
    /// `counts[nbins+1]` overflow.
    counts: Vec<u64>,
    total: u64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Default for LogHistogram {
    /// The [`LogHistogram::latency_ms`] layout.
    fn default() -> Self {
        Self::latency_ms()
    }
}

impl LogHistogram {
    /// Cover `[lo, hi)` with geometric bins of width factor `growth`.
    pub fn new(lo: f64, hi: f64, growth: f64) -> Self {
        assert!(lo > 0.0 && lo.is_finite(), "LogHistogram: lo must be > 0");
        assert!(hi > lo && hi.is_finite(), "LogHistogram: hi must be > lo");
        assert!(
            growth > 1.0 && growth.is_finite(),
            "LogHistogram: growth must be > 1"
        );
        let nbins = ((hi / lo).ln() / growth.ln()).ceil() as usize;
        Self {
            lo,
            growth,
            inv_log_growth: 1.0 / growth.ln(),
            counts: vec![0; nbins + 2],
            total: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    /// The serve path's latency histogram: 1 µs to ~2.8 h in
    /// milliseconds at ≤ 2% relative percentile error (~1.2k bins).
    pub fn latency_ms() -> Self {
        Self::new(1e-3, 1e7, 1.02)
    }

    fn nbins(&self) -> usize {
        self.counts.len() - 2
    }

    pub fn add(&mut self, x: f64) {
        assert!(x.is_finite() && x >= 0.0, "LogHistogram: bad value {x}");
        let slot = if x < self.lo {
            0
        } else {
            let i = ((x / self.lo).ln() * self.inv_log_growth).floor().max(0.0) as usize;
            (i + 1).min(self.counts.len() - 1)
        };
        self.counts[slot] += 1;
        self.total += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Nearest-rank percentile, `p` in [0, 100]. NaN when empty. The
    /// returned value is within a factor `growth` of the exact order
    /// statistic for values in `[lo, hi)` (see the type docs for the
    /// under/overflow edges).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
        if self.total == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0 * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (slot, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let v = if slot == 0 {
                    self.lo
                } else if slot == self.counts.len() - 1 {
                    self.max
                } else {
                    // Upper edge of geometric bin `slot - 1`.
                    self.lo * self.growth.powi(slot as i32)
                };
                return v.clamp(self.min, self.max);
            }
        }
        unreachable!("histogram total/count desync");
    }

    /// Merge another histogram with the identical bin layout.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert!(
            self.lo == other.lo
                && self.growth == other.growth
                && self.counts.len() == other.counts.len(),
            "LogHistogram merge: mismatched bin layouts"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Integer-binned histogram with occurrence counts — the building block of
/// Spork's conditional worker-count distribution ℍ (Alg 2).
#[derive(Clone, Debug, Default)]
pub struct CountHistogram {
    counts: std::collections::BTreeMap<u32, u64>,
    total: u64,
}

impl CountHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, value: u32) {
        *self.counts.entry(value).or_insert(0) += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Distinct observed values (ascending) — Alg 2's candidate bins.
    pub fn bins(&self) -> impl Iterator<Item = u32> + '_ {
        self.counts.keys().copied()
    }

    /// (value, probability) pairs over the empirical distribution.
    pub fn probs(&self) -> impl Iterator<Item = (u32, f64)> + '_ {
        let total = self.total as f64;
        self.counts.iter().map(move |(&v, &c)| (v, c as f64 / total))
    }

    pub fn min_bin(&self) -> Option<u32> {
        self.counts.keys().next().copied()
    }

    pub fn max_bin(&self) -> Option<u32> {
        self.counts.keys().next_back().copied()
    }

    /// Probability-weighted mean of the distribution.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.counts
            .iter()
            .map(|(&v, &c)| v as f64 * c as f64)
            .sum::<f64>()
            / self.total as f64
    }
}

/// Running mean keyed for 𝕃 (average worker lifetime conditioned on
/// allocated count) — cheap, no sample storage.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeanTracker {
    n: u64,
    mean: f64,
}

impl MeanTracker {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Geometric mean over positive values (used for reporting speedup tables).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn histogram_probs_sum_to_one() {
        let mut h = CountHistogram::new();
        for v in [3u32, 3, 5, 7, 7, 7] {
            h.add(v);
        }
        let total: f64 = h.probs().map(|(_, p)| p).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert_eq!(h.bins().collect::<Vec<_>>(), vec![3, 5, 7]);
        assert_eq!(h.min_bin(), Some(3));
        assert_eq!(h.max_bin(), Some(7));
        assert!((h.mean() - (3.0 * 2.0 + 5.0 + 7.0 * 3.0) / 6.0).abs() < 1e-12);
    }

    #[test]
    fn mean_tracker() {
        let mut m = MeanTracker::default();
        for x in [2.0, 4.0, 6.0] {
            m.add(x);
        }
        assert!((m.mean() - 4.0).abs() < 1e-12);
        assert_eq!(m.count(), 3);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[3.32, 1.88]) - 2.498).abs() < 0.01); // paper's 6.25x ~= 3.32*1.88
    }

    /// Exact nearest-rank percentile over a sorted slice (the reference
    /// the log histogram's error bound is stated against).
    fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
        let rank = ((p / 100.0 * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    #[test]
    fn log_histogram_empty_and_single_sample_edges() {
        let h = LogHistogram::latency_ms();
        assert!(h.is_empty());
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());

        // A single sample is exact at every percentile: the clamp to the
        // observed [min, max] collapses the bin to the value itself.
        let mut h = LogHistogram::latency_ms();
        h.add(37.25);
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(h.percentile(p), 37.25, "p{p}");
        }
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 37.25);
        assert_eq!(h.max(), 37.25);
    }

    #[test]
    fn log_histogram_percentile_error_bound_vs_exact_sample() {
        // Randomized latency-shaped distributions: uniform, log-uniform
        // (4 decades), and a heavy Pareto tail. The histogram percentile
        // must stay within its documented relative error (growth - 1) of
        // the exact nearest-rank order statistic — the same order
        // statistics an exact `Sample` sorts to answer from.
        let mut rng = crate::util::rng::Rng::new(42);
        let growth = 1.02;
        for dist in 0..3 {
            let mut h = LogHistogram::new(1e-3, 1e7, growth);
            let mut s = Sample::new();
            let mut xs: Vec<f64> = Vec::new();
            for _ in 0..5000 {
                let x = match dist {
                    0 => rng.range_f64(0.5, 500.0),
                    1 => 10f64.powf(rng.range_f64(-1.0, 3.0)),
                    _ => rng.pareto(5.0, 1.2).min(9e6),
                };
                h.add(x);
                s.add(x);
                xs.push(x);
            }
            xs.sort_by(|a, b| a.total_cmp(b));
            for p in [1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
                let exact = nearest_rank(&xs, p);
                let got = h.percentile(p);
                let rel = (got - exact).abs() / exact;
                assert!(
                    rel <= growth - 1.0 + 1e-9,
                    "dist {dist} p{p}: hist {got} vs exact {exact} (rel {rel:.4})"
                );
                // And the interpolating Sample percentile lies between
                // adjacent order statistics, so the histogram brackets it
                // within one bin + one rank step.
                let sp = s.percentile(p);
                assert!(
                    got >= xs[0] && got <= xs[xs.len() - 1] && sp >= xs[0],
                    "dist {dist} p{p}: out of observed range"
                );
            }
            assert_eq!(h.count(), 5000);
            assert!((h.mean() - s.mean()).abs() / s.mean() < 1e-9);
        }
    }

    #[test]
    fn log_histogram_percentiles_are_monotone() {
        let mut rng = crate::util::rng::Rng::new(7);
        let mut h = LogHistogram::latency_ms();
        for _ in 0..2000 {
            h.add(rng.pareto(2.0, 1.1).min(1e6));
        }
        let ps = [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0];
        for w in ps.windows(2) {
            assert!(h.percentile(w[0]) <= h.percentile(w[1]) + 1e-12);
        }
        assert_eq!(h.percentile(100.0), h.max());
    }

    #[test]
    fn log_histogram_under_and_overflow_are_clamped_and_conserved() {
        let mut h = LogHistogram::new(1.0, 100.0, 1.1);
        h.add(0.001); // underflow
        h.add(0.002); // underflow
        h.add(10.0);
        h.add(5000.0); // overflow
        assert_eq!(h.count(), 4);
        // Underflow reports at most lo (clamped to the observed min).
        assert!(h.percentile(1.0) <= 1.0);
        assert!(h.percentile(1.0) >= 0.001);
        // Overflow reports the exact observed max.
        assert_eq!(h.percentile(100.0), 5000.0);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 5000.0);
    }

    #[test]
    fn log_histogram_merge_matches_sequential() {
        let mut rng = crate::util::rng::Rng::new(11);
        let xs: Vec<f64> = (0..1000).map(|_| rng.range_f64(0.1, 2000.0)).collect();
        let mut all = LogHistogram::latency_ms();
        let mut a = LogHistogram::latency_ms();
        let mut b = LogHistogram::latency_ms();
        for (i, &x) in xs.iter().enumerate() {
            all.add(x);
            if i % 3 == 0 {
                a.add(x);
            } else {
                b.add(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for p in [5.0, 50.0, 99.0, 99.9] {
            assert_eq!(a.percentile(p), all.percentile(p), "p{p}");
        }
        assert!((a.mean() - all.mean()).abs() < 1e-9);
    }
}
