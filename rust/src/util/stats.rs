//! Summary statistics, percentiles, and streaming accumulators used by the
//! simulator metrics, the Spork predictor, and the experiment harness.

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Exact percentile over a stored sample (fine at our sample sizes; the
/// latency-critical paths use counters, not this).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// The stored values (sorted iff a percentile was taken).
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN in Sample"));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0,100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = rank - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Integer-binned histogram with occurrence counts — the building block of
/// Spork's conditional worker-count distribution ℍ (Alg 2).
#[derive(Clone, Debug, Default)]
pub struct CountHistogram {
    counts: std::collections::BTreeMap<u32, u64>,
    total: u64,
}

impl CountHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, value: u32) {
        *self.counts.entry(value).or_insert(0) += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Distinct observed values (ascending) — Alg 2's candidate bins.
    pub fn bins(&self) -> impl Iterator<Item = u32> + '_ {
        self.counts.keys().copied()
    }

    /// (value, probability) pairs over the empirical distribution.
    pub fn probs(&self) -> impl Iterator<Item = (u32, f64)> + '_ {
        let total = self.total as f64;
        self.counts.iter().map(move |(&v, &c)| (v, c as f64 / total))
    }

    pub fn min_bin(&self) -> Option<u32> {
        self.counts.keys().next().copied()
    }

    pub fn max_bin(&self) -> Option<u32> {
        self.counts.keys().next_back().copied()
    }

    /// Probability-weighted mean of the distribution.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.counts
            .iter()
            .map(|(&v, &c)| v as f64 * c as f64)
            .sum::<f64>()
            / self.total as f64
    }
}

/// Running mean keyed for 𝕃 (average worker lifetime conditioned on
/// allocated count) — cheap, no sample storage.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeanTracker {
    n: u64,
    mean: f64,
}

impl MeanTracker {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Geometric mean over positive values (used for reporting speedup tables).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn histogram_probs_sum_to_one() {
        let mut h = CountHistogram::new();
        for v in [3u32, 3, 5, 7, 7, 7] {
            h.add(v);
        }
        let total: f64 = h.probs().map(|(_, p)| p).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert_eq!(h.bins().collect::<Vec<_>>(), vec![3, 5, 7]);
        assert_eq!(h.min_bin(), Some(3));
        assert_eq!(h.max_bin(), Some(7));
        assert!((h.mean() - (3.0 * 2.0 + 5.0 + 7.0 * 3.0) / 6.0).abs() < 1e-12);
    }

    #[test]
    fn mean_tracker() {
        let mut m = MeanTracker::default();
        for x in [2.0, 4.0, 6.0] {
            m.add(x);
        }
        assert!((m.mean() - 4.0).abs() < 1e-12);
        assert_eq!(m.count(), 3);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[3.32, 1.88]) - 2.498).abs() < 0.01); // paper's 6.25x ~= 3.32*1.88
    }
}
