//! A total-order wrapper for `f64` sort/index keys.
//!
//! `f64` is only `PartialOrd`, which forces `partial_cmp(..).unwrap()`
//! comparators into hot sort paths — and those panic mid-run the moment a
//! NaN slips into a trace. [`OrdF64`] carries IEEE 754 `total_cmp` order
//! instead (NaN sorts deterministically after +inf), so ordered indexes
//! and k-way merges stay panic-free; NaN rejection happens loudly at
//! validation boundaries (trace loading / source construction), not in
//! the middle of a simulation.

use std::cmp::Ordering;

/// `f64` with `Ord`/`Eq` via [`f64::total_cmp`]. Suitable as a `BTreeSet`
/// / heap key: the total order refines the usual numeric order on
/// non-NaN values (with `-0.0 < +0.0`).
#[derive(Clone, Copy, Debug)]
pub struct OrdF64(pub f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_like_f64_on_numbers() {
        let mut v = [OrdF64(3.0), OrdF64(-1.0), OrdF64(0.5)];
        v.sort();
        assert_eq!(v.map(|x| x.0), [-1.0, 0.5, 3.0]);
    }

    #[test]
    fn nan_is_ordered_not_panicking() {
        let mut v = [OrdF64(f64::NAN), OrdF64(1.0), OrdF64(f64::INFINITY)];
        v.sort(); // must not panic
        assert_eq!(v[0].0, 1.0);
        assert_eq!(v[1].0, f64::INFINITY);
        assert!(v[2].0.is_nan());
    }

    #[test]
    fn usable_as_btree_key() {
        use std::collections::BTreeSet;
        let mut s = BTreeSet::new();
        s.insert((OrdF64(2.0), 1u32));
        s.insert((OrdF64(1.0), 2u32));
        s.insert((OrdF64(1.0), 1u32));
        // Ordered by (value, id): (1.0, 1) < (1.0, 2) < (2.0, 1).
        let order: Vec<u32> = s.iter().map(|&(_, id)| id).collect();
        assert_eq!(order, vec![1, 2, 1]);
    }
}
