//! Trace (de)serialization: CSV arrival files (`time,size` rows, one file
//! per app) and a JSON manifest for multi-app workloads. Lets experiments
//! be re-run bit-identically from saved traces and lets users bring their
//! own traces.

use super::{AppTrace, Arrival};
use anyhow::{Context, Result};
use std::path::Path;

/// Write one app's arrivals as CSV with a `# duration=<s>` header comment.
pub fn save_csv(app: &AppTrace, path: &Path) -> Result<()> {
    let mut out = String::with_capacity(app.len() * 24 + 64);
    out.push_str(&format!("# app={} duration={}\n", app.name, app.duration));
    out.push_str("time,size\n");
    for a in &app.arrivals {
        out.push_str(&format!("{:.6},{:.6}\n", a.time, a.size));
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Load a CSV trace written by [`save_csv`] (or hand-authored: header
/// comment optional, `time,size` header row optional).
pub fn load_csv(path: &Path) -> Result<AppTrace> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let mut name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "trace".to_string());
    let mut duration: Option<f64> = None;
    let mut arrivals = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        // Header-token grammar shared with the streaming reader
        // (`source::CsvSource::open_impl`) — keep the two in sync.
        if let Some(rest) = line.strip_prefix('#') {
            for tok in rest.split_whitespace() {
                if let Some(v) = tok.strip_prefix("duration=") {
                    duration = Some(v.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "{}:{}: bad duration '{v}' in header",
                            path.display(),
                            lineno + 1
                        )
                    })?);
                } else if let Some(v) = tok.strip_prefix("app=") {
                    name = v.to_string();
                }
            }
            continue;
        }
        if line.starts_with("time") {
            continue; // header row
        }
        let (t, s) = line
            .split_once(',')
            .with_context(|| format!("{}:{}: expected 'time,size'", path.display(), lineno + 1))?;
        let time: f64 = t
            .trim()
            .parse()
            .with_context(|| format!("{}:{}: bad time", path.display(), lineno + 1))?;
        let size: f64 = s
            .trim()
            .parse()
            .with_context(|| format!("{}:{}: bad size", path.display(), lineno + 1))?;
        anyhow::ensure!(
            time.is_finite() && time >= 0.0,
            "{}:{}: time must be finite and >= 0",
            path.display(),
            lineno + 1
        );
        anyhow::ensure!(
            size > 0.0 && size.is_finite(),
            "{}:{}: size must be finite and > 0",
            path.display(),
            lineno + 1
        );
        arrivals.push(Arrival { time, size });
    }
    arrivals.sort_by(|a, b| a.time.total_cmp(&b.time));
    let duration = duration.unwrap_or_else(|| arrivals.last().map_or(0.0, |a| a.time));
    Ok(AppTrace::new(&name, arrivals, duration))
}

/// Save a workload (multiple apps) into a directory with a manifest.
pub fn save_workload(apps: &[AppTrace], dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut manifest = String::from("# spork workload manifest\n");
    for app in apps {
        let file = format!("{}.csv", app.name);
        save_csv(app, &dir.join(&file))?;
        manifest.push_str(&file);
        manifest.push('\n');
    }
    std::fs::write(dir.join("MANIFEST"), manifest)?;
    Ok(())
}

/// Load a workload directory written by [`save_workload`].
pub fn load_workload(dir: &Path) -> Result<Vec<AppTrace>> {
    let manifest = std::fs::read_to_string(dir.join("MANIFEST"))
        .with_context(|| format!("reading manifest in {}", dir.display()))?;
    let mut apps = Vec::new();
    for line in manifest.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        apps.push(load_csv(&dir.join(line))?);
    }
    Ok(apps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("spork-io-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> AppTrace {
        AppTrace::new(
            "demo",
            vec![
                Arrival { time: 0.25, size: 0.01 },
                Arrival { time: 1.5, size: 0.01 },
                Arrival { time: 3.75, size: 0.02 },
            ],
            10.0,
        )
    }

    #[test]
    fn csv_round_trip() {
        let d = tmpdir("csv");
        let p = d.join("demo.csv");
        save_csv(&sample(), &p).unwrap();
        let back = load_csv(&p).unwrap();
        assert_eq!(back.name, "demo");
        assert_eq!(back.duration, 10.0);
        assert_eq!(back.len(), 3);
        assert!((back.arrivals[2].size - 0.02).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn load_unsorted_and_headerless() {
        let d = tmpdir("raw");
        let p = d.join("raw.csv");
        std::fs::write(&p, "5.0,0.1\n1.0,0.2\n").unwrap();
        let t = load_csv(&p).unwrap();
        assert_eq!(t.len(), 2);
        assert!(t.arrivals[0].time < t.arrivals[1].time);
        assert_eq!(t.duration, 5.0); // falls back to last arrival
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_bad_rows() {
        let d = tmpdir("bad");
        let p = d.join("bad.csv");
        std::fs::write(&p, "1.0,0.1\nnot-a-row\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::write(&p, "1.0,-0.5\n").unwrap();
        assert!(load_csv(&p).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn csv_source_streams_what_load_csv_materializes() {
        use crate::trace::source::{ArrivalSource, CsvSource};
        let d = tmpdir("src");
        let p = d.join("demo.csv");
        save_csv(&sample(), &p).unwrap();
        let eager = load_csv(&p).unwrap();
        let mut src = CsvSource::open(&p).unwrap();
        assert_eq!(src.name(), "demo");
        assert_eq!(src.duration(), eager.duration);
        let streamed: Vec<Arrival> = std::iter::from_fn(|| src.next_arrival()).collect();
        assert_eq!(streamed, eager.arrivals);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn csv_source_requires_duration_and_order() {
        use crate::trace::source::{ArrivalSource, CsvSource};
        let d = tmpdir("srcbad");
        // Headerless: no window length available for streaming.
        let p = d.join("raw.csv");
        std::fs::write(&p, "1.0,0.1\n2.0,0.1\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        let mut src = CsvSource::open_with_duration(&p, 5.0).unwrap();
        assert_eq!(src.duration(), 5.0);
        assert!(src.next_arrival().is_some());
        // Out-of-order rows fail loudly at the offending line.
        let q = d.join("unsorted.csv");
        std::fs::write(&q, "# duration=9\n5.0,0.1\n1.0,0.2\n").unwrap();
        let mut src = CsvSource::open(&q).unwrap();
        assert!(src.next_arrival().is_some());
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            src.next_arrival()
        }))
        .is_err();
        assert!(panicked, "out-of-order row must fail loudly");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn workload_round_trip() {
        let d = tmpdir("wl");
        let mut a = sample();
        a.name = "app-a".into();
        let mut b = sample();
        b.name = "app-b".into();
        save_workload(&[a, b], &d).unwrap();
        let back = load_workload(&d).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "app-a");
        assert_eq!(back[1].name, "app-b");
        let _ = std::fs::remove_dir_all(&d);
    }
}
