//! The b-model self-similar traffic generator (Wang et al., ICDE 2002 —
//! paper reference [87]).
//!
//! The b-model recursively bisects a volume of work: at each level the
//! current segment's volume is split between its two halves with bias `b`
//! (fraction `b` to one half, `1-b` to the other, the side chosen at
//! random). `b = 0.5` yields a uniform series; `b = 0.75` yields highly
//! variable, bursty series (the paper observes >20x load differences
//! between some consecutive intervals at 0.75).

use crate::util::rng::Rng;

/// Generate a self-similar volume series of length `len` (padded up to the
/// next power of two internally, then truncated) whose values sum to
/// `total`. Values are non-negative.
pub fn bmodel_series(rng: &mut Rng, b: f64, len: usize, total: f64) -> Vec<f64> {
    assert!((0.5..1.0).contains(&b), "bias must be in [0.5, 1.0), got {b}");
    assert!(len > 0);
    let levels = (len as f64).log2().ceil() as u32;
    let n = 1usize << levels;
    let mut cur = vec![total];
    for _ in 0..levels {
        let mut next = Vec::with_capacity(cur.len() * 2);
        for &v in &cur {
            let (hi, lo) = (v * b, v * (1.0 - b));
            if rng.chance(0.5) {
                next.push(hi);
                next.push(lo);
            } else {
                next.push(lo);
                next.push(hi);
            }
        }
        cur = next;
    }
    debug_assert_eq!(cur.len(), n);
    // Truncate to requested length, rescaling so the kept prefix sums to
    // `total` (keeps mean rate comparable across lengths).
    cur.truncate(len);
    let s: f64 = cur.iter().sum();
    if s > 0.0 {
        let k = total / s;
        for v in &mut cur {
            *v *= k;
        }
    }
    cur
}

/// Generate per-slot request *rates* with the given mean rate: a b-model
/// series normalized so the average is `mean_rate` (the §3.2 setting:
/// "per-second request arrival rates using the b-model").
pub fn bmodel_rates(rng: &mut Rng, b: f64, slots: usize, mean_rate: f64) -> Vec<f64> {
    bmodel_series(rng, b, slots, mean_rate * slots as f64)
}

/// Burstiness diagnostic: max over consecutive-slot ratios (paper's ">20x
/// difference in load for some consecutive intervals" at b = 0.75).
pub fn max_consecutive_ratio(series: &[f64]) -> f64 {
    series
        .windows(2)
        .filter(|w| w[0].min(w[1]) > 0.0)
        .map(|w| w[0].max(w[1]) / w[0].min(w[1]))
        .fold(1.0, f64::max)
}

/// Coefficient of variation — a scalar burstiness summary used in tests.
pub fn cov(series: &[f64]) -> f64 {
    let n = series.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mean = series.iter().sum::<f64>() / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = series.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_total_volume() {
        let mut rng = Rng::new(1);
        for &len in &[1usize, 7, 64, 100, 4096] {
            let s = bmodel_series(&mut rng, 0.7, len, 1000.0);
            assert_eq!(s.len(), len);
            let total: f64 = s.iter().sum();
            assert!((total - 1000.0).abs() < 1e-6, "len={len} total={total}");
            assert!(s.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn b_half_is_uniform() {
        let mut rng = Rng::new(2);
        let s = bmodel_series(&mut rng, 0.5, 256, 256.0);
        for v in &s {
            assert!((v - 1.0).abs() < 1e-9);
        }
        assert!(cov(&s) < 1e-9);
    }

    #[test]
    fn burstiness_increases_with_bias() {
        let mut rng = Rng::new(3);
        let c55 = cov(&bmodel_series(&mut rng, 0.55, 4096, 1e6));
        let c65 = cov(&bmodel_series(&mut rng, 0.65, 4096, 1e6));
        let c75 = cov(&bmodel_series(&mut rng, 0.75, 4096, 1e6));
        assert!(c55 < c65 && c65 < c75, "cov: {c55} {c65} {c75}");
    }

    #[test]
    fn high_bias_shows_large_consecutive_swings() {
        // Paper: b=0.75 implies >~20x differences for some consecutive
        // intervals on hour-long (3600 slot) traces.
        let mut rng = Rng::new(4);
        let s = bmodel_series(&mut rng, 0.75, 3600, 3.6e7);
        assert!(max_consecutive_ratio(&s) > 20.0);
    }

    #[test]
    fn mean_rate_is_respected() {
        let mut rng = Rng::new(5);
        let r = bmodel_rates(&mut rng, 0.7, 3600, 10_000.0);
        let mean = r.iter().sum::<f64>() / r.len() as f64;
        assert!((mean - 10_000.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_bias_below_half() {
        let mut rng = Rng::new(6);
        bmodel_series(&mut rng, 0.3, 16, 1.0);
    }
}
