//! Production-like workload synthesis.
//!
//! The paper evaluates repurposed **Azure Functions** [75] and **Alibaba
//! microservices** [51] traces. Those datasets are not redistributable in
//! this environment, so — per the substitution rule recorded in DESIGN.md —
//! we synthesize app populations that match the *published statistics* the
//! paper relies on:
//!
//! * Table 7 app counts per request-size bucket (Azure: 13 short / 101
//!   medium / 241 long heavy-demand apps; Alibaba: 99 short / 31 medium).
//! * Heavy-demand skew: "fewer than 25% of the applications require more
//!   than one worker at any point, but they constitute over 94% of the
//!   compute demand" — we model only that heavy subset (as the paper does)
//!   and draw per-app demand from a Pareto tail.
//! * Per-minute arrival rates with diurnal drift plus self-similar
//!   (b-model) variability; the Azure serverless workload is burstier than
//!   the Alibaba RPC workload (§5.2 observes Spork's margin over FPGAs is
//!   smaller on Alibaba "due to a less bursty workload").
//! * Two-hour windows, time-varying Poisson interarrivals with per-minute
//!   linear rate interpolation (§5.1).

use super::source::PoissonSource;
use super::{bmodel, poisson, AppTrace, RateTrace};
use crate::config::SizeBucket;
use crate::util::rng::Rng;

/// Which production dataset to emulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    AzureFunctions,
    AlibabaMicroservices,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::AzureFunctions => "azure",
            Dataset::AlibabaMicroservices => "alibaba",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "azure" => Dataset::AzureFunctions,
            "alibaba" => Dataset::AlibabaMicroservices,
            _ => return None,
        })
    }

    /// Table 7: number of heavy-demand applications per size bucket.
    pub fn app_count(&self, bucket: SizeBucket) -> usize {
        match (self, bucket) {
            (Dataset::AzureFunctions, SizeBucket::Short) => 13,
            (Dataset::AzureFunctions, SizeBucket::Medium) => 101,
            (Dataset::AzureFunctions, SizeBucket::Long) => 241,
            (Dataset::AlibabaMicroservices, SizeBucket::Short) => 99,
            (Dataset::AlibabaMicroservices, SizeBucket::Medium) => 31,
            (Dataset::AlibabaMicroservices, SizeBucket::Long) => 0, // N/A in Table 7
        }
    }

    /// Self-similarity bias of per-minute rates. Azure Functions
    /// invocations are burstier than Alibaba's high-rate RPC microservices.
    fn burstiness(&self) -> f64 {
        // Calibrated so Spork's predictor-vs-ideal gap tracks the paper's
        // Table 8 (real production rates are diurnal-smooth with bursts;
        // the b-model at high bias churns at every scale).
        match self {
            Dataset::AzureFunctions => 0.62,
            Dataset::AlibabaMicroservices => 0.54,
        }
    }

    /// Diurnal swing amplitude across the 2 h window (fraction of base).
    fn diurnal_amplitude(&self) -> f64 {
        match self {
            Dataset::AzureFunctions => 0.35,
            Dataset::AlibabaMicroservices => 0.20,
        }
    }
}

/// Generation parameters for a production-like workload.
#[derive(Clone, Copy, Debug)]
pub struct ProductionParams {
    pub dataset: Dataset,
    pub bucket: SizeBucket,
    /// Window length in seconds (paper: two hours).
    pub duration: f64,
    /// Demand scale factor: 1.0 targets paper-scale demand (tens of
    /// workers per heavy app). Experiments may reduce this to bound
    /// simulated request counts; recorded in EXPERIMENTS.md.
    pub scale: f64,
    /// Optionally cap the number of apps (None = full Table 7 count).
    pub max_apps: Option<usize>,
}

impl ProductionParams {
    pub fn paper(dataset: Dataset, bucket: SizeBucket) -> Self {
        Self {
            dataset,
            bucket,
            duration: 7200.0,
            scale: 1.0,
            max_apps: None,
        }
    }
}

/// Synthesize the heavy-demand app population for one dataset × bucket.
///
/// Each app gets: a fixed request size log-uniform in the bucket (request
/// sizes are stable and known — §4.5), a Pareto-tailed average demand, and
/// a per-minute rate series = base × diurnal drift × b-model multiplicative
/// variability, converted to Poisson arrivals.
pub fn generate(params: &ProductionParams, rng: &mut Rng) -> Vec<AppTrace> {
    let n_apps = params
        .max_apps
        .map_or(params.dataset.app_count(params.bucket), |m| {
            m.min(params.dataset.app_count(params.bucket))
        });
    let mut apps = Vec::with_capacity(n_apps);
    for i in 0..n_apps {
        let mut app_rng = rng.fork(i as u64);
        apps.push(generate_app(params, i, &mut app_rng));
    }
    apps
}

/// Streaming counterpart of [`generate`]: one lazy per-app source per
/// heavy-demand app. Per-app setup (size, demand, per-minute rates) is
/// materialized eagerly — it is O(minutes), not O(arrivals) — and the
/// Poisson synthesis streams, so a paper-scale two-hour population holds
/// only its rate grids in memory. Sequence-identical to [`generate`] for
/// the same parent RNG (pinned by `rust/tests/source_parity.rs`).
pub fn app_sources(params: &ProductionParams, rng: &mut Rng) -> Vec<PoissonSource> {
    let n_apps = params
        .max_apps
        .map_or(params.dataset.app_count(params.bucket), |m| {
            m.min(params.dataset.app_count(params.bucket))
        });
    (0..n_apps)
        .map(|i| app_source(params, i, rng.fork(i as u64)))
        .collect()
}

/// The shared per-app setup: request size, demand draw, and the
/// per-minute rate grid (base × diurnal drift × b-model variability).
fn app_rates(params: &ProductionParams, rng: &mut Rng) -> (f64, RateTrace) {
    let (lo, hi) = params.bucket.bounds();
    // Log-uniform request size within the bucket.
    let size = lo * (hi / lo).powf(rng.f64());

    // Average steady-state demand in *workers* (CPU-equivalents), Pareto
    // tail starting at 2 workers (the heavy-demand subset: >1 worker),
    // alpha ~ 1.16 (80/20-ish skew), capped to keep runtimes sane.
    let avg_workers = (rng.pareto(2.0, 1.16) * params.scale).min(120.0 * params.scale);
    let mean_rate = avg_workers / size; // req/s so that demand = avg_workers

    let minutes = (params.duration / 60.0).ceil() as usize;
    // Self-similar multiplicative variability around the mean.
    let variability =
        bmodel::bmodel_rates(rng, params.dataset.burstiness(), minutes, 1.0);
    // Diurnal drift: slow sinusoid with random phase across the window.
    let phase = rng.f64() * std::f64::consts::TAU;
    let amp = params.dataset.diurnal_amplitude();
    let rates: Vec<f64> = (0..minutes)
        .map(|m| {
            let x = m as f64 / minutes.max(1) as f64;
            let diurnal = 1.0 + amp * (std::f64::consts::TAU * x + phase).sin();
            (mean_rate * variability[m] * diurnal).max(0.0)
        })
        .collect();
    (size, RateTrace::new(60.0, rates))
}

fn app_name(params: &ProductionParams, index: usize) -> String {
    format!(
        "{}-{}-app{:03}",
        params.dataset.name(),
        params.bucket.name(),
        index
    )
}

fn generate_app(params: &ProductionParams, index: usize, rng: &mut Rng) -> AppTrace {
    let (size, rate_trace) = app_rates(params, rng);
    let arrivals = poisson::poisson_arrivals(rng, &rate_trace, |_| size);
    AppTrace::new(&app_name(params, index), arrivals, params.duration)
}

fn app_source(params: &ProductionParams, index: usize, mut rng: Rng) -> PoissonSource {
    let (size, rate_trace) = app_rates(params, &mut rng);
    // The minute-aligned rate grid may overrun a non-minute-aligned
    // window; `generate` has always kept those arrivals, so the streaming
    // path does too.
    PoissonSource::new(
        &app_name(params, index),
        rng,
        rate_trace,
        params.duration,
        Box::new(move |_| size),
    )
    .with_unclipped_window()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::bmodel::cov;

    fn small(dataset: Dataset, bucket: SizeBucket) -> ProductionParams {
        ProductionParams {
            dataset,
            bucket,
            duration: 1800.0,
            scale: 0.3,
            max_apps: Some(6),
        }
    }

    #[test]
    fn app_counts_match_table7() {
        assert_eq!(Dataset::AzureFunctions.app_count(SizeBucket::Short), 13);
        assert_eq!(Dataset::AzureFunctions.app_count(SizeBucket::Medium), 101);
        assert_eq!(Dataset::AzureFunctions.app_count(SizeBucket::Long), 241);
        assert_eq!(Dataset::AlibabaMicroservices.app_count(SizeBucket::Short), 99);
        assert_eq!(Dataset::AlibabaMicroservices.app_count(SizeBucket::Medium), 31);
        assert_eq!(Dataset::AlibabaMicroservices.app_count(SizeBucket::Long), 0);
    }

    #[test]
    fn sizes_within_bucket_and_stable_per_app() {
        let mut rng = Rng::new(1);
        let apps = generate(&small(Dataset::AzureFunctions, SizeBucket::Short), &mut rng);
        assert_eq!(apps.len(), 6);
        for app in &apps {
            assert!(!app.is_empty(), "{} generated empty", app.name);
            let s0 = app.arrivals[0].size;
            assert!((0.010..=0.100).contains(&s0), "size {s0} out of bucket");
            assert!(app.arrivals.iter().all(|a| a.size == s0));
        }
    }

    #[test]
    fn azure_burstier_than_alibaba() {
        // Compare mean per-minute-count CoV across several seeds.
        let mut az_cov = 0.0;
        let mut al_cov = 0.0;
        let n = 8;
        for seed in 0..n {
            let mut rng = Rng::new(seed);
            let az = generate(&small(Dataset::AzureFunctions, SizeBucket::Short), &mut rng);
            let mut rng = Rng::new(seed);
            let al = generate(
                &small(Dataset::AlibabaMicroservices, SizeBucket::Short),
                &mut rng,
            );
            let mcov = |apps: &[AppTrace]| {
                apps.iter()
                    .map(|a| {
                        let c: Vec<f64> = a
                            .counts_per_interval(60.0)
                            .into_iter()
                            .map(|x| x as f64)
                            .collect();
                        cov(&c)
                    })
                    .sum::<f64>()
                    / apps.len() as f64
            };
            az_cov += mcov(&az);
            al_cov += mcov(&al);
        }
        assert!(
            az_cov > al_cov,
            "azure cov {az_cov} should exceed alibaba {al_cov}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let p = small(Dataset::AlibabaMicroservices, SizeBucket::Medium);
        let a = generate(&p, &mut Rng::new(9));
        let b = generate(&p, &mut Rng::new(9));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            assert_eq!(x.arrivals.first().map(|v| v.time), y.arrivals.first().map(|v| v.time));
        }
    }

    #[test]
    fn demand_skew_is_heavy_tailed() {
        let mut rng = Rng::new(3);
        let p = ProductionParams {
            max_apps: Some(40),
            ..small(Dataset::AzureFunctions, SizeBucket::Medium)
        };
        // Pareto demand: top quarter of apps should carry most of the work.
        let mut works: Vec<f64> = generate(&p, &mut rng).iter().map(|a| a.total_work()).collect();
        works.sort_by(|a, b| b.total_cmp(a));
        let total: f64 = works.iter().sum();
        let top_quarter: f64 = works[..works.len() / 4].iter().sum();
        assert!(
            top_quarter / total > 0.5,
            "top 25% carries {:.0}% of demand",
            100.0 * top_quarter / total
        );
    }
}
