//! Streaming arrival sources: pull-based, time-ordered request streams.
//!
//! The original workload layer materialized every trace as a
//! `Vec<Arrival>`, which caps trace length at available memory — a
//! two-hour window is fine, a datacenter-scale million-request replay is
//! not. [`ArrivalSource`] is the streaming alternative: a pull-based
//! iterator of time-ordered [`Arrival`]s that generators produce lazily
//! (chunk by chunk) and the sim driver consumes one look-ahead at a
//! time, so simulation memory is bounded by the worker pool and the
//! in-flight event heap — never by trace length.
//!
//! Every generator source is **sequence-identical** to its Vec-building
//! counterpart for the same RNG stream (pinned by
//! `rust/tests/source_parity.rs`):
//!
//! | streaming source          | materialized counterpart          |
//! |---------------------------|-----------------------------------|
//! | [`PoissonSource`]         | [`poisson::poisson_arrivals`]     |
//! | [`synthetic_source`]      | [`super::synthetic_app_dt`]       |
//! | `production::app_sources` | `production::generate`            |
//! | [`CsvSource`]             | [`io::load_csv`] (sorted input)   |
//! | [`MergeSource`]           | stable sort of the concatenation  |
//!
//! [`AppTrace`] stays as the thin `collect()` adapter
//! ([`AppTrace::from_source`]) so callers that genuinely need random
//! access (fitting searches, oracle construction from saved traces)
//! migrate incrementally.
//!
//! [`poisson::poisson_arrivals`]: super::poisson::poisson_arrivals
//! [`io::load_csv`]: super::io::load_csv

use super::{bmodel, Arrival, RateTrace};
use crate::util::ordf64::OrdF64;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A pull-based, time-ordered stream of request arrivals.
///
/// Contract: [`next_arrival`](Self::next_arrival) yields arrivals with
/// nondecreasing, finite `time` and positive, finite `size`; `duration()`
/// is the nominal observation-window length and is available *before*
/// the first pull (the sim driver needs the window end up front to gate
/// ticks and fleet pinning). Generators whose rate grid is coarser than
/// the window may overrun `duration()` by up to one grid slot
/// (`production::app_sources`, matching its materialized counterpart);
/// interval-binning consumers clamp such arrivals into the final bucket,
/// exactly as `AppTrace::work_per_interval` always has. Sources fail
/// loudly (panic with context) on invalid data instead of emitting NaNs
/// that would corrupt a running simulation.
pub trait ArrivalSource {
    /// The next arrival, or `None` when the stream is exhausted.
    fn next_arrival(&mut self) -> Option<Arrival>;

    /// Duration of the observation window (>= every yielded time).
    fn duration(&self) -> f64;

    /// Stream name (app name for per-app sources).
    fn name(&self) -> &str;

    /// Exact number of arrivals this source will still yield, or `None`
    /// when the count cannot be known up front. This is a *hint with an
    /// exactness contract*, not an estimate: when `Some(n)` is returned,
    /// exactly `n` more `next_arrival` calls succeed (the sim driver
    /// asserts this at exhaustion). Materialized sources know their
    /// count; generator sources ([`PoissonSource`]) return `None` because
    /// the count is a function of RNG draws not yet made — callers that
    /// replay a deterministic stream (the §5.1 fitting searches) learn
    /// the exact count from a prior full pass and attach it via
    /// [`KnownLen`]. The early-abort feasibility predicate
    /// (`sim::run_source_bounded`) arms only when this is `Some`.
    fn len_hint(&self) -> Option<u64> {
        None
    }
}

/// Borrowing source over an already-materialized [`super::AppTrace`] —
/// the adapter that lets every source-based API accept legacy traces.
pub struct TraceSource<'a> {
    trace: &'a super::AppTrace,
    pos: usize,
}

impl<'a> TraceSource<'a> {
    pub fn new(trace: &'a super::AppTrace) -> Self {
        Self { trace, pos: 0 }
    }
}

impl ArrivalSource for TraceSource<'_> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        let a = self.trace.arrivals.get(self.pos).copied();
        self.pos += a.is_some() as usize;
        a
    }

    fn duration(&self) -> f64 {
        self.trace.duration
    }

    fn name(&self) -> &str {
        &self.trace.name
    }

    fn len_hint(&self) -> Option<u64> {
        Some((self.trace.arrivals.len() - self.pos) as u64)
    }
}

/// Owning source over a sorted arrival vector (tests, hand-built
/// workloads, [`super::AppTrace::into_source`]).
pub struct VecSource {
    name: String,
    duration: f64,
    arrivals: std::vec::IntoIter<Arrival>,
}

impl VecSource {
    pub fn new(name: &str, arrivals: Vec<Arrival>, duration: f64) -> Self {
        debug_assert!(arrivals.windows(2).all(|w| w[0].time <= w[1].time));
        Self {
            name: name.to_string(),
            duration,
            arrivals: arrivals.into_iter(),
        }
    }
}

impl ArrivalSource for VecSource {
    fn next_arrival(&mut self) -> Option<Arrival> {
        self.arrivals.next()
    }

    fn duration(&self) -> f64 {
        self.duration
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.arrivals.len() as u64)
    }
}

/// Size assignment callback: arrival time → request size. Boxed so
/// [`PoissonSource`] stays object-safe and non-generic.
pub type SizeFn = Box<dyn FnMut(f64) -> f64>;

/// Streaming non-homogeneous Poisson synthesis — the lazy counterpart of
/// [`super::poisson::poisson_arrivals`]. One integration step (1 s) of
/// arrivals is generated per chunk: the step's count is
/// Poisson(∫λ dt), instants are uniform in the step and sorted, sizes are
/// assigned in time order. RNG consumption and `size_of` call order are
/// identical to the materialized path, so the yielded sequence is too.
pub struct PoissonSource {
    name: String,
    rng: Rng,
    rates: RateTrace,
    size_of: SizeFn,
    /// Yield cutoff: arrivals at `time >= window` are dropped (the
    /// synthetic pipeline truncates the final partial rate slot).
    window: f64,
    /// Reported observation-window length.
    duration: f64,
    /// Next integration-step start; `t >= rates.duration()` = exhausted.
    t: f64,
    buf: Vec<Arrival>,
    buf_pos: usize,
}

impl PoissonSource {
    pub fn new(name: &str, rng: Rng, rates: RateTrace, duration: f64, size_of: SizeFn) -> Self {
        assert!(
            duration.is_finite() && duration >= 0.0,
            "{name}: non-finite trace duration"
        );
        assert!(
            rates.rates.iter().all(|r| r.is_finite() && *r >= 0.0),
            "{name}: rate trace contains negative or non-finite rates"
        );
        Self {
            name: name.to_string(),
            rng,
            rates,
            size_of,
            window: duration,
            duration,
            t: 0.0,
            buf: Vec::new(),
            buf_pos: 0,
        }
    }

    /// Keep every arrival the rate trace generates, even past the
    /// reported duration (the production pipeline's historical behavior:
    /// the rate grid is minute-aligned and may overrun the window).
    pub fn with_unclipped_window(mut self) -> Self {
        self.window = f64::INFINITY;
        self
    }

    /// Generate the next 1 s integration step into `buf`. Mirrors one
    /// loop iteration of `poisson_arrivals` exactly (same RNG draws, same
    /// within-step sort, same size_of call order).
    fn refill(&mut self) -> bool {
        const STEP: f64 = super::poisson::STEP;
        let end = self.rates.duration();
        self.buf.clear();
        self.buf_pos = 0;
        while self.t < end && self.buf.is_empty() {
            let step = STEP.min(end - self.t);
            let lam = 0.5 * (self.rates.rate_at(self.t) + self.rates.rate_at(self.t + step)) * step;
            let count = self.rng.poisson(lam);
            for _ in 0..count {
                let at = self.t + self.rng.f64() * step;
                self.buf.push(Arrival { time: at, size: 0.0 });
            }
            self.buf.sort_by(|a, b| a.time.total_cmp(&b.time));
            for a in &mut self.buf {
                a.size = (self.size_of)(a.time);
            }
            self.t += step;
        }
        !self.buf.is_empty()
    }
}

impl ArrivalSource for PoissonSource {
    fn next_arrival(&mut self) -> Option<Arrival> {
        loop {
            if self.buf_pos < self.buf.len() {
                let a = self.buf[self.buf_pos];
                self.buf_pos += 1;
                if a.time < self.window {
                    return Some(a);
                }
                // Past the window: arrivals are time-ordered, so every
                // remaining one is out too — the yielded sequence equals
                // the materialized path's `time < duration` filter
                // without generating the discarded tail.
                self.t = self.rates.duration();
                self.buf_pos = self.buf.len();
                return None;
            }
            if !self.refill() {
                return None;
            }
        }
    }

    fn duration(&self) -> f64 {
        self.duration
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Streaming §5.1 synthetic workload — the lazy counterpart of
/// [`super::synthetic_app_dt`]: b-model per-slot rates (O(slots) memory,
/// not O(arrivals)) driving chunked Poisson synthesis. Takes the RNG by
/// value; pass the same stream the materialized path would consume
/// (e.g. `Rng::for_stream(seed_base, seed)`) for an identical sequence.
pub fn synthetic_source(
    name: &str,
    mut rng: Rng,
    burstiness: f64,
    duration: f64,
    mean_rate: f64,
    request_size: f64,
    dt: f64,
) -> PoissonSource {
    let slots = ((duration / dt).ceil() as usize).max(1);
    let rates = bmodel::bmodel_rates(&mut rng, burstiness, slots, mean_rate);
    PoissonSource::new(
        name,
        rng,
        RateTrace::new(dt, rates),
        duration,
        Box::new(move |_| request_size),
    )
}

/// K-way merge combinator: combines per-app sources into one
/// time-ordered stream (multi-app workloads replayed through a shared
/// pool, or multiple CSV shards of one long trace). Heap-based: O(log k)
/// per arrival, ties broken by source index (== stable sort of the
/// concatenation, pinned by the parity suite). Duration is the max of
/// the inputs'.
pub struct MergeSource<'a> {
    name: String,
    duration: f64,
    sources: Vec<Box<dyn ArrivalSource + 'a>>,
    heads: Vec<Option<Arrival>>,
    heap: BinaryHeap<Reverse<(OrdF64, usize)>>,
}

impl<'a> MergeSource<'a> {
    pub fn new(name: &str, mut sources: Vec<Box<dyn ArrivalSource + 'a>>) -> Self {
        let duration = sources.iter().map(|s| s.duration()).fold(0.0, f64::max);
        let mut heads = Vec::with_capacity(sources.len());
        let mut heap = BinaryHeap::with_capacity(sources.len());
        for (i, src) in sources.iter_mut().enumerate() {
            let head = src.next_arrival();
            if let Some(a) = head {
                heap.push(Reverse((OrdF64(a.time), i)));
            }
            heads.push(head);
        }
        Self {
            name: name.to_string(),
            duration,
            sources,
            heads,
            heap,
        }
    }
}

impl ArrivalSource for MergeSource<'_> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        let Reverse((_, i)) = self.heap.pop()?;
        let out = self.heads[i].take().expect("merge head/heap desync");
        if let Some(next) = self.sources[i].next_arrival() {
            debug_assert!(next.time >= out.time, "source {i} not time-ordered");
            self.heap.push(Reverse((OrdF64(next.time), i)));
            self.heads[i] = Some(next);
        }
        Some(out)
    }

    fn duration(&self) -> f64 {
        self.duration
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn len_hint(&self) -> Option<u64> {
        // Exact only if every child is exact: each in-flight head counts
        // one arrival already pulled from its child but not yet yielded.
        let mut total = self.heads.iter().flatten().count() as u64;
        for src in &self.sources {
            total += src.len_hint()?;
        }
        Some(total)
    }
}

/// Attaches an externally-known exact arrival count to a source whose own
/// [`ArrivalSource::len_hint`] is `None` — the adapter that lets the
/// §5.1 fitting searches arm the early-abort predicate on *generator*
/// streams. The count must come from a prior full pass over the *same*
/// deterministic stream (the oracle pass counts arrivals as it bins
/// work); the wrapper enforces exactness loudly: yielding past the
/// declared count, or exhausting short of it, is a panic, because a
/// miscount would invalidate the abort proof (`misses / total` would no
/// longer be the final run's miss fraction).
pub struct KnownLen<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    remaining: u64,
}

impl<'a> KnownLen<'a> {
    pub fn new(inner: Box<dyn ArrivalSource + 'a>, total: u64) -> Self {
        Self {
            inner,
            remaining: total,
        }
    }
}

impl ArrivalSource for KnownLen<'_> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        match self.inner.next_arrival() {
            Some(a) => {
                assert!(
                    self.remaining > 0,
                    "KnownLen('{}'): source yielded more arrivals than its declared count",
                    self.inner.name()
                );
                self.remaining -= 1;
                Some(a)
            }
            None => {
                assert!(
                    self.remaining == 0,
                    "KnownLen('{}'): source exhausted {} arrivals short of its declared count",
                    self.inner.name(),
                    self.remaining
                );
                None
            }
        }
    }

    fn duration(&self) -> f64 {
        self.inner.duration()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.remaining)
    }
}

/// State shared by the consumers of one [`tee`] fan-out: the inner
/// stream, pulled exactly once, plus the window of arrivals some live
/// consumer still needs. `buf[i]` is arrival `base + i` of the stream;
/// the front is trimmed as soon as the slowest live consumer moves past
/// it, so buffering is bounded by the spread between the fastest and
/// slowest live consumer (O(1) under the sim's lockstep stepping), never
/// by stream length.
struct TeeShared<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    buf: VecDeque<Arrival>,
    /// Absolute stream index of `buf[0]`.
    base: u64,
    /// Total arrivals pulled from `inner` so far.
    pulled: u64,
    /// Per-consumer next absolute index; `None` once dropped.
    pos: Vec<Option<u64>>,
    /// Whether `inner` is exhausted.
    done: bool,
}

impl TeeShared<'_> {
    /// Drop buffered arrivals no live consumer can still request.
    fn trim(&mut self) {
        let floor = self.pos.iter().flatten().copied().min().unwrap_or(self.pulled);
        while self.base < floor {
            self.buf.pop_front();
            self.base += 1;
        }
    }
}

/// One consumer of a [`tee`] fan-out. Yields exactly the inner stream —
/// same order, same count, same per-arrival bits — independent of how
/// its siblings interleave their pulls (pinned by the source-parity
/// suite). Dropping a consumer mid-stream releases its stake in the
/// shared buffer without perturbing siblings, which is how aborted
/// candidates leave a lockstep fitting batch early.
pub struct TeeSource<'a> {
    shared: Rc<RefCell<TeeShared<'a>>>,
    idx: usize,
    name: String,
    duration: f64,
}

/// Fan a single pull-based stream out to `n` consumers. The inner source
/// is pulled exactly once per arrival no matter how many consumers read
/// it — the point of the adapter: one traversal of an expensive stream
/// (synthesis, CSV parse) feeds a whole lockstep candidate batch.
pub fn tee(inner: Box<dyn ArrivalSource + '_>, n: usize) -> Vec<TeeSource<'_>> {
    let name = inner.name().to_string();
    let duration = inner.duration();
    let shared = Rc::new(RefCell::new(TeeShared {
        inner,
        buf: VecDeque::new(),
        base: 0,
        pulled: 0,
        pos: vec![Some(0); n],
        done: false,
    }));
    (0..n)
        .map(|idx| TeeSource {
            shared: Rc::clone(&shared),
            idx,
            name: name.clone(),
            duration,
        })
        .collect()
}

impl ArrivalSource for TeeSource<'_> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        let mut s = self.shared.borrow_mut();
        let my = s.pos[self.idx].expect("tee consumer polled after drop");
        if my < s.pulled {
            // Some faster sibling already pulled this arrival.
            let a = s.buf[(my - s.base) as usize];
            s.pos[self.idx] = Some(my + 1);
            if my == s.base {
                s.trim();
            }
            return Some(a);
        }
        if s.done {
            return None;
        }
        match s.inner.next_arrival() {
            Some(a) => {
                s.buf.push_back(a);
                s.pulled += 1;
                s.pos[self.idx] = Some(my + 1);
                if my == s.base {
                    s.trim();
                }
                Some(a)
            }
            None => {
                s.done = true;
                None
            }
        }
    }

    fn duration(&self) -> f64 {
        self.duration
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn len_hint(&self) -> Option<u64> {
        // Exact whenever the inner hint is: arrivals already buffered
        // ahead of this consumer plus whatever the inner source will
        // still yield. Stays exact after the inner stream exhausts.
        let s = self.shared.borrow();
        let my = s.pos[self.idx].expect("tee consumer polled after drop");
        let ahead = s.pulled - my;
        if s.done {
            Some(ahead)
        } else {
            s.inner.len_hint().map(|h| h + ahead)
        }
    }
}

impl Drop for TeeSource<'_> {
    fn drop(&mut self) {
        let mut s = self.shared.borrow_mut();
        s.pos[self.idx] = None;
        s.trim();
    }
}

/// Streaming CSV trace reader: replays `time,size` rows (the
/// [`super::io::save_csv`] format) without ever holding the arrivals in
/// memory — the path for multi-gigabyte production traces.
///
/// Requirements, enforced loudly:
/// * rows must already be sorted by time (use [`super::io::load_csv`]
///   for small unsorted files — sorting needs materialization);
/// * times/sizes must be finite, sizes positive (NaN-bearing traces fail
///   at the offending line, not deep inside a simulation);
/// * the `# duration=<s>` header must be present or a duration passed
///   via [`CsvSource::open_with_duration`] (a stream's window end cannot
///   be known before its last row).
pub struct CsvSource {
    name: String,
    path: PathBuf,
    duration: f64,
    reader: std::io::BufReader<std::fs::File>,
    line: String,
    lineno: usize,
    last_time: f64,
    /// First data row, if the header scan ran into it (yielded first).
    pending: Option<Arrival>,
}

impl CsvSource {
    /// Open a CSV trace whose header carries `# duration=<s>`.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_impl(path, None)
    }

    /// Open a CSV trace with an explicit window length (for headerless
    /// hand-authored files).
    pub fn open_with_duration(path: &Path, duration: f64) -> Result<Self> {
        Self::open_impl(path, Some(duration))
    }

    fn open_impl(path: &Path, duration: Option<f64>) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut src = Self {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_else(|| "trace".to_string()),
            path: path.to_path_buf(),
            duration: duration.unwrap_or(f64::NAN),
            reader: std::io::BufReader::new(file),
            line: String::new(),
            lineno: 0,
            last_time: f64::NEG_INFINITY,
            pending: None,
        };
        // Consume the leading header block — comments, blank lines, and
        // the optional `time,size` row in any order (everything load_csv
        // accepts) — so `# app=` / `# duration=` apply before the first
        // pull. The first data row encountered ends the scan and is
        // stashed for the first pull. Header-token grammar shared with
        // `io::load_csv` — keep the two in sync.
        let mut first_row: Option<String> = None;
        loop {
            src.line.clear();
            src.lineno += 1;
            if src.reader.read_line(&mut src.line)? == 0 {
                break; // header-only (or empty) file
            }
            let line = src.line.trim().to_string();
            if line.is_empty() || line.starts_with("time") {
                continue;
            }
            if let Some(rest) = line.strip_prefix('#') {
                for tok in rest.split_whitespace() {
                    if let Some(v) = tok.strip_prefix("duration=") {
                        // An explicit open_with_duration overrides the header.
                        if duration.is_none() {
                            src.duration = v.parse().map_err(|_| {
                                anyhow::anyhow!(
                                    "{}:{}: bad duration '{v}' in header",
                                    path.display(),
                                    src.lineno
                                )
                            })?;
                        }
                    } else if let Some(v) = tok.strip_prefix("app=") {
                        src.name = v.to_string();
                    }
                }
                continue;
            }
            first_row = Some(line);
            break;
        }
        anyhow::ensure!(
            src.duration.is_finite() && src.duration >= 0.0,
            "{}: streaming a CSV trace needs its window length up front — \
             add a `# duration=<seconds>` header (save_csv writes one) or \
             use CsvSource::open_with_duration",
            path.display()
        );
        if let Some(row) = first_row {
            src.pending = Some(src.parse_row(&row));
        }
        Ok(src)
    }

    /// Parse and validate one data row (`time,size`), panicking with
    /// file:line context on malformed or out-of-order data.
    fn parse_row(&mut self, line: &str) -> Arrival {
        let Some((t, s)) = line.split_once(',') else {
            self.bad("expected 'time,size'");
        };
        let Ok(time) = t.trim().parse::<f64>() else {
            self.bad("bad time");
        };
        let Ok(size) = s.trim().parse::<f64>() else {
            self.bad("bad size");
        };
        if !time.is_finite() || time < 0.0 {
            self.bad("non-finite or negative time");
        }
        if !(size > 0.0 && size.is_finite()) {
            self.bad("size must be finite and > 0");
        }
        if time < self.last_time {
            self.bad("rows out of time order (sort the file, or load it via trace::io::load_csv)");
        }
        self.last_time = time;
        Arrival { time, size }
    }

    fn bad(&self, what: &str) -> ! {
        panic!("{}:{}: {}", self.path.display(), self.lineno, what);
    }
}

impl ArrivalSource for CsvSource {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if let Some(a) = self.pending.take() {
            return Some(a);
        }
        loop {
            self.line.clear();
            self.lineno += 1;
            match self.reader.read_line(&mut self.line) {
                Ok(0) => return None,
                Ok(_) => {}
                Err(e) => panic!("{}: read error: {e}", self.path.display()),
            }
            let line = std::mem::take(&mut self.line);
            let row = line.trim();
            if row.is_empty() || row.starts_with('#') || row.starts_with("time") {
                self.line = line;
                continue;
            }
            let a = self.parse_row(row);
            self.line = line;
            return Some(a);
        }
    }

    fn duration(&self) -> f64 {
        self.duration
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Partition an app set across router shards: item `i` goes to shard
/// `i % shards` (empty shards allowed when `shards > items`). Round-robin
/// is the sharded router's fixed assignment rule — it depends only on
/// item index and shard count, never on item contents or timing, which is
/// half of the shard-count determinism contract (the other half: results
/// are merged back in item-index order, which round-robin makes a cheap
/// k-way interleave).
pub fn partition_round_robin<T>(items: Vec<T>, shards: usize) -> Vec<Vec<T>> {
    let shards = shards.max(1);
    let mut parts: Vec<Vec<T>> = (0..shards).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        parts[i % shards].push(item);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::super::AppTrace;
    use super::*;

    fn collect(src: &mut dyn ArrivalSource) -> Vec<Arrival> {
        std::iter::from_fn(|| src.next_arrival()).collect()
    }

    #[test]
    fn trace_source_round_trips() {
        let t = AppTrace::new(
            "x",
            vec![
                Arrival { time: 0.5, size: 0.01 },
                Arrival { time: 1.5, size: 0.02 },
            ],
            4.0,
        );
        let mut s = TraceSource::new(&t);
        assert_eq!(s.duration(), 4.0);
        assert_eq!(s.name(), "x");
        assert_eq!(collect(&mut s), t.arrivals);
        assert_eq!(s.next_arrival(), None); // fused
    }

    #[test]
    fn poisson_source_matches_materialized() {
        let rates = RateTrace::new(1.0, vec![5.0, 50.0, 0.0, 100.0]);
        let expect =
            super::super::poisson::poisson_arrivals(&mut Rng::new(9), &rates, |t| t + 1.0);
        let mut src = PoissonSource::new(
            "p",
            Rng::new(9),
            rates,
            4.0,
            Box::new(|t| t + 1.0),
        );
        assert_eq!(collect(&mut src), expect);
    }

    #[test]
    fn synthetic_source_matches_materialized() {
        let expect = super::super::synthetic_app_dt(
            "s",
            &mut Rng::new(4),
            0.65,
            90.0,
            40.0,
            0.010,
            60.0,
        );
        let mut src = synthetic_source("s", Rng::new(4), 0.65, 90.0, 40.0, 0.010, 60.0);
        assert_eq!(src.duration(), 90.0);
        assert_eq!(collect(&mut src), expect.arrivals);
    }

    #[test]
    fn merge_is_time_ordered_and_complete() {
        let a = AppTrace::new(
            "a",
            vec![
                Arrival { time: 0.0, size: 0.1 },
                Arrival { time: 2.0, size: 0.1 },
            ],
            3.0,
        );
        let b = AppTrace::new(
            "b",
            vec![
                Arrival { time: 0.0, size: 0.2 },
                Arrival { time: 1.0, size: 0.2 },
            ],
            5.0,
        );
        let mut m = MergeSource::new(
            "ab",
            vec![Box::new(TraceSource::new(&a)), Box::new(TraceSource::new(&b))],
        );
        assert_eq!(m.duration(), 5.0);
        let got = collect(&mut m);
        assert_eq!(got.len(), 4);
        assert!(got.windows(2).all(|w| w[0].time <= w[1].time));
        // Tie at t=0 goes to the earlier source.
        assert_eq!(got[0].size, 0.1);
        assert_eq!(got[1].size, 0.2);
    }

    #[test]
    fn vec_source_yields_all() {
        let arr = vec![Arrival { time: 1.0, size: 0.5 }];
        let mut s = VecSource::new("v", arr.clone(), 2.0);
        assert_eq!(collect(&mut s), arr);
    }

    #[test]
    fn len_hints_are_exact_where_known() {
        let t = AppTrace::new(
            "x",
            vec![
                Arrival { time: 0.5, size: 0.01 },
                Arrival { time: 1.5, size: 0.02 },
                Arrival { time: 2.5, size: 0.03 },
            ],
            4.0,
        );
        let mut s = TraceSource::new(&t);
        assert_eq!(s.len_hint(), Some(3));
        s.next_arrival();
        assert_eq!(s.len_hint(), Some(2));

        let mut v = VecSource::new("v", t.arrivals.clone(), 4.0);
        assert_eq!(v.len_hint(), Some(3));
        v.next_arrival();
        assert_eq!(v.len_hint(), Some(2));

        // Merge of exact sources is exact (heads in flight included).
        let m = MergeSource::new(
            "mm",
            vec![Box::new(TraceSource::new(&t)), Box::new(TraceSource::new(&t))],
        );
        assert_eq!(m.len_hint(), Some(6));

        // Generator sources cannot know their count up front.
        let p = synthetic_source("s", Rng::new(4), 0.65, 90.0, 40.0, 0.010, 60.0);
        assert_eq!(p.len_hint(), None);
    }

    #[test]
    fn known_len_attaches_exact_count() {
        let expect = super::super::synthetic_app_dt(
            "s",
            &mut Rng::new(4),
            0.65,
            90.0,
            40.0,
            0.010,
            60.0,
        );
        let src = synthetic_source("s", Rng::new(4), 0.65, 90.0, 40.0, 0.010, 60.0);
        let mut k = KnownLen::new(Box::new(src), expect.len() as u64);
        assert_eq!(k.len_hint(), Some(expect.len() as u64));
        assert_eq!(collect(&mut k), expect.arrivals);
        assert_eq!(k.len_hint(), Some(0));
        assert_eq!(k.next_arrival(), None); // exhaustion matches the count
    }

    #[test]
    #[should_panic(expected = "short of its declared count")]
    fn known_len_panics_on_short_stream() {
        let arr = vec![Arrival { time: 1.0, size: 0.5 }];
        let mut k = KnownLen::new(Box::new(VecSource::new("v", arr, 2.0)), 2);
        k.next_arrival();
        k.next_arrival(); // inner exhausts one short of the declared 2
    }

    #[test]
    fn tee_consumers_each_see_the_serial_stream() {
        // Three consumers of one Poisson stream, pulled in a skewed
        // round-robin (0 pulls one, 1 pulls two, 2 pulls three per
        // round): each must observe exactly the serial sequence.
        let expect = collect(&mut synthetic_source("t", Rng::new(8), 0.6, 60.0, 80.0, 0.010, 60.0));
        let inner = synthetic_source("t", Rng::new(8), 0.6, 60.0, 80.0, 0.010, 60.0);
        let mut cons = tee(Box::new(inner), 3);
        let mut got: Vec<Vec<Arrival>> = vec![Vec::new(); 3];
        let mut open = true;
        while open {
            open = false;
            for (i, c) in cons.iter_mut().enumerate() {
                for _ in 0..=i {
                    if let Some(a) = c.next_arrival() {
                        got[i].push(a);
                        open = true;
                    }
                }
            }
        }
        for (i, g) in got.iter().enumerate() {
            assert_eq!(g, &expect, "tee consumer {i} diverged from the serial stream");
        }
        for c in &mut cons {
            assert_eq!(c.next_arrival(), None, "exhausted consumers stay exhausted");
        }
    }

    #[test]
    fn tee_buffer_is_bounded_by_consumer_spread_and_drop_releases_it() {
        let t = AppTrace::new(
            "x",
            (0..100)
                .map(|i| Arrival { time: i as f64, size: 0.01 })
                .collect(),
            100.0,
        );
        let expect = t.arrivals.clone();
        let mut cons = tee(Box::new(t.into_source()), 3);
        let (mut fast, mid, mut slow) = {
            let mut it = cons.into_iter();
            (it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
        };
        // The fast consumer runs 40 ahead; the shared buffer must hold
        // that whole span for the two stalled siblings.
        let mut fast_got = Vec::new();
        for _ in 0..40 {
            fast_got.push(fast.next_arrival().unwrap());
        }
        assert_eq!(fast.shared.borrow().buf.len(), 40);
        // Dropping both laggards releases the buffered span entirely.
        drop(mid);
        drop(slow.next_arrival().unwrap()); // slow consumes one first
        drop(slow);
        assert_eq!(fast.shared.borrow().buf.len(), 0, "drop must trim the buffer");
        // The surviving consumer still sees the exact serial stream.
        while let Some(a) = fast.next_arrival() {
            fast_got.push(a);
        }
        assert_eq!(fast_got, expect);
    }

    #[test]
    fn tee_len_hint_stays_exact_through_interleaving() {
        let t = AppTrace::new(
            "x",
            (0..10)
                .map(|i| Arrival { time: i as f64, size: 0.01 })
                .collect(),
            10.0,
        );
        let mut cons = tee(Box::new(KnownLen::new(Box::new(t.into_source()), 10)), 2);
        assert_eq!(cons[0].len_hint(), Some(10));
        assert_eq!(cons[1].len_hint(), Some(10));
        // Consumer 0 pulls 4: its own hint shrinks, its sibling's holds
        // (buffered-ahead arrivals count toward the sibling's remainder).
        for _ in 0..4 {
            cons[0].next_arrival();
        }
        assert_eq!(cons[0].len_hint(), Some(6));
        assert_eq!(cons[1].len_hint(), Some(10));
        cons[1].next_arrival();
        assert_eq!(cons[1].len_hint(), Some(9));
        // Drain consumer 0 past exhaustion: hints stay exact to the end.
        while cons[0].next_arrival().is_some() {}
        assert_eq!(cons[0].len_hint(), Some(0));
        assert_eq!(cons[1].len_hint(), Some(9));
    }

    #[test]
    fn tee_over_merge_source_preserves_the_merged_order() {
        let a = AppTrace::new(
            "a",
            vec![
                Arrival { time: 0.0, size: 0.1 },
                Arrival { time: 2.0, size: 0.1 },
            ],
            3.0,
        );
        let b = AppTrace::new(
            "b",
            vec![
                Arrival { time: 0.0, size: 0.2 },
                Arrival { time: 1.0, size: 0.2 },
            ],
            5.0,
        );
        let serial = {
            let mut m = MergeSource::new(
                "ab",
                vec![Box::new(TraceSource::new(&a)), Box::new(TraceSource::new(&b))],
            );
            collect(&mut m)
        };
        let m = MergeSource::new(
            "ab",
            vec![Box::new(TraceSource::new(&a)), Box::new(TraceSource::new(&b))],
        );
        let mut cons = tee(Box::new(m), 2);
        // Consumer 0 drains completely before consumer 1 starts — the
        // worst-case spread (whole stream buffered).
        let first = collect(&mut cons[0]);
        let second = collect(&mut cons[1]);
        assert_eq!(first, serial);
        assert_eq!(second, serial);
    }

    #[test]
    fn partition_round_robin_covers_and_interleaves() {
        let parts = partition_round_robin((0..7).collect::<Vec<_>>(), 3);
        assert_eq!(parts, vec![vec![0, 3, 6], vec![1, 4], vec![2, 5]]);
        // Degenerate shapes: one shard takes everything; more shards than
        // items leaves the surplus shards empty; zero shards clamps to 1.
        assert_eq!(partition_round_robin(vec![9, 8], 1), vec![vec![9, 8]]);
        assert_eq!(
            partition_round_robin(vec![1], 3),
            vec![vec![1], vec![], vec![]]
        );
        assert_eq!(partition_round_robin(vec![1, 2], 0), vec![vec![1, 2]]);
        // Merging partitions back in item-index order is a k-way
        // interleave — the determinism contract's other half.
        let parts = partition_round_robin((0..10).collect::<Vec<_>>(), 4);
        let mut merged = Vec::new();
        let mut cursors = vec![0usize; parts.len()];
        for i in 0..10 {
            let s = i % parts.len();
            merged.push(parts[s][cursors[s]]);
            cursors[s] += 1;
        }
        assert_eq!(merged, (0..10).collect::<Vec<_>>());
    }
}
