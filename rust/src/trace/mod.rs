//! Workload traces: rate series, request arrival streams, and generators.
//!
//! Three representations flow through the system:
//!
//! * [`RateTrace`] — piecewise request *rates* (req/s per slot). This is what
//!   the b-model produces, what §3's fluid/optimal analysis consumes, and
//!   what drives non-homogeneous Poisson arrival synthesis.
//! * [`ArrivalSource`] — a pull-based, time-ordered *stream* of
//!   [`Arrival`]s (time + size): the constant-memory representation the
//!   simulator and serving runtime consume, generated lazily by the
//!   synthesis pipelines or replayed from CSV without materialization.
//! * [`AppTrace`] — a fully materialized arrival vector for one
//!   application: a thin `collect()` of a source, kept for callers that
//!   need random access (fitting searches, saved-trace tooling).

pub mod bmodel;
pub mod io;
pub mod poisson;
pub mod production;
pub mod source;

pub use source::{
    partition_round_robin, synthetic_source, tee, ArrivalSource, CsvSource, KnownLen,
    MergeSource, PoissonSource, TeeSource, TraceSource, VecSource,
};

use crate::util::rng::Rng;

/// Piecewise-constant request-rate series: `rates[i]` is the average rate
/// (requests/second) during `[i*dt, (i+1)*dt)`.
#[derive(Clone, Debug, PartialEq)]
pub struct RateTrace {
    pub dt: f64,
    pub rates: Vec<f64>,
}

impl RateTrace {
    pub fn new(dt: f64, rates: Vec<f64>) -> Self {
        assert!(dt > 0.0);
        Self { dt, rates }
    }

    pub fn duration(&self) -> f64 {
        self.dt * self.rates.len() as f64
    }

    /// Total expected number of requests.
    pub fn total_requests(&self) -> f64 {
        self.rates.iter().sum::<f64>() * self.dt
    }

    pub fn mean_rate(&self) -> f64 {
        if self.rates.is_empty() {
            0.0
        } else {
            self.rates.iter().sum::<f64>() / self.rates.len() as f64
        }
    }

    pub fn peak_rate(&self) -> f64 {
        self.rates.iter().cloned().fold(0.0, f64::max)
    }

    /// Re-bin to a coarser slot width (must be a multiple of `dt`), averaging
    /// rates. Used to view per-second b-model output at scheduler-interval
    /// granularity.
    pub fn rebin(&self, new_dt: f64) -> RateTrace {
        let k = (new_dt / self.dt).round() as usize;
        assert!(k >= 1, "new_dt must be >= dt");
        assert!(
            (new_dt - k as f64 * self.dt).abs() < 1e-9,
            "new_dt must be a multiple of dt"
        );
        let rates = self
            .rates
            .chunks(k)
            .map(|c| c.iter().sum::<f64>() / c.len() as f64)
            .collect();
        RateTrace { dt: new_dt, rates }
    }

    /// Linear-interpolated instantaneous rate at time `t`, treating each
    /// slot's value as the rate at the slot midpoint (§5.1: "rates change
    /// linearly within each minute"). Clamped at the ends.
    pub fn rate_at(&self, t: f64) -> f64 {
        if self.rates.is_empty() {
            return 0.0;
        }
        let x = t / self.dt - 0.5;
        if x <= 0.0 {
            return self.rates[0];
        }
        let i = x.floor() as usize;
        if i + 1 >= self.rates.len() {
            return *self.rates.last().unwrap();
        }
        let frac = x - i as f64;
        self.rates[i] * (1.0 - frac) + self.rates[i + 1] * frac
    }
}

/// One request arrival: time (s from trace start) and size (service time in
/// CPU-seconds; the paper assumes sizes are known — §4.5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    pub time: f64,
    pub size: f64,
}

/// A per-application arrival stream.
#[derive(Clone, Debug)]
pub struct AppTrace {
    pub name: String,
    pub arrivals: Vec<Arrival>,
    /// Duration of the observation window (>= last arrival time).
    pub duration: f64,
}

impl AppTrace {
    pub fn new(name: &str, arrivals: Vec<Arrival>, duration: f64) -> Self {
        debug_assert!(arrivals.windows(2).all(|w| w[0].time <= w[1].time));
        Self {
            name: name.to_string(),
            arrivals,
            duration,
        }
    }

    /// Materialize a streaming source — the thin `collect()` adapter that
    /// lets source-producing pipelines feed legacy `Vec`-consuming
    /// callers. Streams of unbounded length should instead flow straight
    /// into [`crate::sim::run_source`].
    pub fn from_source(src: &mut dyn ArrivalSource) -> AppTrace {
        let name = src.name().to_string();
        let duration = src.duration();
        let mut arrivals = Vec::new();
        while let Some(a) = src.next_arrival() {
            arrivals.push(a);
        }
        AppTrace::new(&name, arrivals, duration)
    }

    /// Borrowing streaming view of this trace (the adapter every
    /// source-based API uses to accept materialized traces).
    pub fn source(&self) -> TraceSource<'_> {
        TraceSource::new(self)
    }

    /// Consume the trace into an owning source.
    pub fn into_source(self) -> VecSource {
        let AppTrace {
            name,
            arrivals,
            duration,
        } = self;
        VecSource::new(&name, arrivals, duration)
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Total work in CPU-seconds.
    pub fn total_work(&self) -> f64 {
        self.arrivals.iter().map(|a| a.size).sum()
    }

    /// Aggregate per-interval demand in CPU-seconds (used by oracle
    /// schedulers and needed-worker computations).
    pub fn work_per_interval(&self, interval: f64) -> Vec<f64> {
        let n = interval_bins(self.duration, interval);
        let mut w = vec![0.0; n];
        for a in &self.arrivals {
            w[interval_index(a.time, interval, n)] += a.size;
        }
        w
    }

    /// Per-interval arrival counts.
    pub fn counts_per_interval(&self, interval: f64) -> Vec<u64> {
        let n = interval_bins(self.duration, interval);
        let mut c = vec![0u64; n];
        for a in &self.arrivals {
            c[interval_index(a.time, interval, n)] += 1;
        }
        c
    }
}

/// Number of `interval`-wide bins covering `duration` (always >= 1) —
/// the single binning rule shared by [`AppTrace::work_per_interval`] /
/// [`AppTrace::counts_per_interval`] and the streaming oracle
/// construction (`sched::Oracle::from_source`), so the materialized and
/// streaming paths can never disagree on interval layout.
pub fn interval_bins(duration: f64, interval: f64) -> usize {
    ((duration / interval).ceil() as usize).max(1)
}

/// Clamped bin index of an arrival at `time` (overruns — e.g. a
/// minute-aligned rate grid past a non-aligned window — land in the
/// final bin).
pub fn interval_index(time: f64, interval: f64, bins: usize) -> usize {
    ((time / interval) as usize).min(bins - 1)
}

/// §5.1's synthetic workload: constant-size requests with **per-minute**
/// b-model rates ("we next generate per-minute request arrival rates based
/// on a self-similar distribution") turned into time-varying Poisson
/// arrivals with linear rate interpolation within each minute.
pub fn synthetic_app(
    name: &str,
    rng: &mut Rng,
    burstiness: f64,
    duration: f64,
    mean_rate: f64,
    request_size: f64,
) -> AppTrace {
    synthetic_app_dt(name, rng, burstiness, duration, mean_rate, request_size, 60.0)
}

/// Synthetic workload with an explicit rate-slot width. §3.2 (Fig 2/3)
/// uses per-second slots (`dt = 1`); §5.1 uses per-minute (`dt = 60`).
pub fn synthetic_app_dt(
    name: &str,
    rng: &mut Rng,
    burstiness: f64,
    duration: f64,
    mean_rate: f64,
    request_size: f64,
    dt: f64,
) -> AppTrace {
    let slots = ((duration / dt).ceil() as usize).max(1);
    let rates = bmodel::bmodel_rates(rng, burstiness, slots, mean_rate);
    let rate_trace = RateTrace::new(dt, rates);
    let arrivals = poisson::poisson_arrivals(rng, &rate_trace, |_| request_size);
    let arrivals = arrivals
        .into_iter()
        .filter(|a| a.time < duration)
        .collect();
    AppTrace::new(name, arrivals, duration)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_trace_aggregates() {
        let t = RateTrace::new(2.0, vec![1.0, 3.0, 5.0]);
        assert_eq!(t.duration(), 6.0);
        assert!((t.total_requests() - 18.0).abs() < 1e-12);
        assert!((t.mean_rate() - 3.0).abs() < 1e-12);
        assert_eq!(t.peak_rate(), 5.0);
    }

    #[test]
    fn rebin_preserves_volume() {
        let t = RateTrace::new(1.0, (0..60).map(|i| i as f64).collect());
        let r = t.rebin(10.0);
        assert_eq!(r.rates.len(), 6);
        assert!((r.total_requests() - t.total_requests()).abs() < 1e-9);
    }

    #[test]
    fn rate_at_interpolates() {
        let t = RateTrace::new(1.0, vec![0.0, 10.0]);
        assert_eq!(t.rate_at(0.0), 0.0); // clamped
        assert!((t.rate_at(1.0) - 5.0).abs() < 1e-12); // midpoint between slots
        assert_eq!(t.rate_at(5.0), 10.0); // clamped end
    }

    #[test]
    fn app_trace_work_binning() {
        let arrivals = vec![
            Arrival { time: 0.5, size: 0.01 },
            Arrival { time: 1.5, size: 0.02 },
            Arrival { time: 9.99, size: 0.03 },
        ];
        let app = AppTrace::new("t", arrivals, 10.0);
        let w = app.work_per_interval(5.0);
        assert_eq!(w.len(), 2);
        assert!((w[0] - 0.03).abs() < 1e-12);
        assert!((w[1] - 0.03).abs() < 1e-12);
        assert!((app.total_work() - 0.06).abs() < 1e-12);
        assert_eq!(app.counts_per_interval(5.0), vec![2, 1]);
    }

    #[test]
    fn synthetic_app_volume_close_to_expected() {
        let mut rng = Rng::new(1);
        let app = synthetic_app("s", &mut rng, 0.6, 600.0, 100.0, 0.010);
        let expected = 600.0 * 100.0;
        let got = app.len() as f64;
        assert!(
            (got - expected).abs() < expected * 0.05,
            "got {got}, expected ~{expected}"
        );
        // arrivals sorted and within window
        assert!(app.arrivals.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(app.arrivals.iter().all(|a| a.time >= 0.0 && a.time <= 600.0));
    }
}
