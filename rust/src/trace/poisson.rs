//! Non-homogeneous Poisson arrival synthesis from a rate trace.
//!
//! §5.1: "We use the request rates to generate two-hour traces with
//! time-varying Poisson interarrivals, assuming that the rates change
//! linearly within each minute." We implement this with per-second
//! integration of the linearly-interpolated rate: in each one-second step
//! the arrival count is Poisson(∫λ dt over the step) and arrival instants
//! are spread uniformly in the step (exchangeability of a Poisson process
//! conditioned on its count).

use super::{Arrival, RateTrace};
use crate::util::rng::Rng;

/// Integration step for arrival placement (seconds). Shared with the
/// streaming [`super::source::PoissonSource`], whose chunking must mirror
/// this loop exactly.
pub(crate) const STEP: f64 = 1.0;

/// Generate sorted arrivals over `rates.duration()`. `size_of` maps arrival
/// time → request size, letting callers use constant sizes (§3.2) or
/// per-app profiles (§5.2).
pub fn poisson_arrivals(
    rng: &mut Rng,
    rates: &RateTrace,
    mut size_of: impl FnMut(f64) -> f64,
) -> Vec<Arrival> {
    let duration = rates.duration();
    let mut arrivals = Vec::with_capacity(rates.total_requests() as usize + 16);
    let mut t = 0.0;
    while t < duration {
        let step = STEP.min(duration - t);
        // Trapezoidal integral of the linearly-interpolated rate.
        let lam = 0.5 * (rates.rate_at(t) + rates.rate_at(t + step)) * step;
        let count = rng.poisson(lam);
        let base = arrivals.len();
        for _ in 0..count {
            let at = t + rng.f64() * step;
            arrivals.push(Arrival {
                time: at,
                size: 0.0, // sized after sorting for determinism by time order
            });
        }
        // Keep arrivals time-sorted within the step. total_cmp: a NaN
        // (impossible here, but this is a hot path) sorts instead of
        // panicking; validation rejects NaNs at the source boundary.
        arrivals[base..].sort_by(|a, b| a.time.total_cmp(&b.time));
        t += step;
    }
    for a in &mut arrivals {
        a.size = size_of(a.time);
    }
    arrivals
}

/// Deterministic arrivals at exactly the per-slot expected counts, evenly
/// spaced — used by tests and by the fluid-model cross-checks where
/// sampling noise is unwanted.
pub fn deterministic_arrivals(
    rates: &RateTrace,
    mut size_of: impl FnMut(f64) -> f64,
) -> Vec<Arrival> {
    let mut arrivals = Vec::new();
    for (i, &r) in rates.rates.iter().enumerate() {
        let t0 = i as f64 * rates.dt;
        let n = (r * rates.dt).round() as usize;
        for k in 0..n {
            let time = t0 + (k as f64 + 0.5) / n as f64 * rates.dt;
            arrivals.push(Arrival {
                time,
                size: size_of(time),
            });
        }
    }
    arrivals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_matches_expectation() {
        let mut rng = Rng::new(1);
        let rates = RateTrace::new(60.0, vec![100.0; 10]); // 10 min at 100/s
        let arr = poisson_arrivals(&mut rng, &rates, |_| 0.01);
        let expected = 600.0 * 100.0;
        assert!(
            (arr.len() as f64 - expected).abs() < expected * 0.03,
            "got {}, expected ~{expected}",
            arr.len()
        );
    }

    #[test]
    fn arrivals_sorted_and_in_window() {
        let mut rng = Rng::new(2);
        let rates = RateTrace::new(1.0, vec![5.0, 50.0, 5.0, 100.0]);
        let arr = poisson_arrivals(&mut rng, &rates, |_| 0.01);
        assert!(arr.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(arr.iter().all(|a| (0.0..=4.0).contains(&a.time)));
    }

    #[test]
    fn tracks_time_varying_rate() {
        let mut rng = Rng::new(3);
        // First half ~0, second half hot: arrivals should concentrate there.
        let mut rates = vec![0.0; 30];
        rates.extend(vec![200.0; 30]);
        let rates = RateTrace::new(1.0, rates);
        let arr = poisson_arrivals(&mut rng, &rates, |_| 0.01);
        let early = arr.iter().filter(|a| a.time < 25.0).count();
        let late = arr.iter().filter(|a| a.time > 35.0).count();
        assert!(late > 50 * early.max(1), "early={early} late={late}");
    }

    #[test]
    fn zero_rate_no_arrivals() {
        let mut rng = Rng::new(4);
        let rates = RateTrace::new(1.0, vec![0.0; 10]);
        assert!(poisson_arrivals(&mut rng, &rates, |_| 0.01).is_empty());
    }

    #[test]
    fn deterministic_counts_exact() {
        let rates = RateTrace::new(2.0, vec![3.0, 0.0, 1.5]);
        let arr = deterministic_arrivals(&rates, |_| 0.5);
        assert_eq!(arr.len(), 6 + 0 + 3);
        assert!(arr.iter().all(|a| a.size == 0.5));
    }

    #[test]
    fn sizes_assigned_via_callback() {
        let mut rng = Rng::new(5);
        let rates = RateTrace::new(1.0, vec![50.0; 4]);
        let arr = poisson_arrivals(&mut rng, &rates, |t| if t < 2.0 { 0.1 } else { 0.2 });
        assert!(arr.iter().all(|a| (a.time < 2.0) == (a.size == 0.1)));
    }
}
