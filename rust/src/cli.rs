//! Lightweight command-line argument parsing (clap is not available in the
//! offline registry).
//!
//! Grammar: `spork <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted. Unknown flags are an error so typos fail
//! loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Declares what a command accepts, for validation + help text.
#[derive(Clone, Debug)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    /// (name, takes_value, help)
    pub opts: Vec<(&'static str, bool, &'static str)>,
}

impl Args {
    /// Parse raw argv (without the program name) against a command spec set.
    pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();

        // Subcommand is the first non-flag token.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap().clone());
            }
        }
        let spec = match &args.subcommand {
            Some(sc) => Some(
                specs
                    .iter()
                    .find(|s| s.name == sc.as_str())
                    .ok_or_else(|| format!("unknown subcommand '{sc}'"))?,
            ),
            None => None,
        };

        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = spec.and_then(|s| s.opts.iter().find(|(n, _, _)| *n == key));
                match decl {
                    Some((_, true, _)) => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                                .clone(),
                        };
                        args.options.insert(key, val);
                    }
                    Some((_, false, _)) => {
                        if inline_val.is_some() {
                            return Err(format!("--{key} does not take a value"));
                        }
                        args.flags.push(key);
                    }
                    None => return Err(format!("unknown option '--{key}'")),
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected a number, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected an integer, got '{v}'")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected an integer, got '{v}'")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub fn render_help(program: &str, about: &str, specs: &[Spec]) -> String {
    let mut out = format!("{program} — {about}\n\nUSAGE:\n  {program} <command> [options]\n\nCOMMANDS:\n");
    for s in specs {
        out.push_str(&format!("  {:<14} {}\n", s.name, s.about));
    }
    out.push_str("\nRun `");
    out.push_str(program);
    out.push_str(" <command> --help` for command options.\n");
    out
}

pub fn render_command_help(program: &str, spec: &Spec) -> String {
    let mut out = format!("{program} {} — {}\n\nOPTIONS:\n", spec.name, spec.about);
    for (name, takes, help) in &spec.opts {
        let lhs = if *takes {
            format!("--{name} <v>")
        } else {
            format!("--{name}")
        };
        out.push_str(&format!("  {:<24} {}\n", lhs, help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![Spec {
            name: "simulate",
            about: "run one simulation",
            opts: vec![
                ("seed", true, "rng seed"),
                ("burstiness", true, "b-model bias"),
                ("verbose", false, "chatty output"),
            ],
        }]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &sv(&["simulate", "--seed", "7", "--burstiness=0.6", "--verbose", "tracefile"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.f64_or("burstiness", 0.5).unwrap(), 0.6);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["tracefile"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["simulate"]), &specs()).unwrap();
        assert_eq!(a.u64_or("seed", 42).unwrap(), 42);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(&sv(&["simulate", "--nope"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["frobnicate"]), &specs()).is_err());
    }

    #[test]
    fn value_required() {
        assert!(Args::parse(&sv(&["simulate", "--seed"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["simulate", "--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&sv(&["simulate", "--seed", "abc"]), &specs()).unwrap();
        assert!(a.u64_or("seed", 0).is_err());
        assert!(a.usize_or("seed", 0).is_err());
    }

    #[test]
    fn usize_parses_and_defaults() {
        let a = Args::parse(&sv(&["simulate", "--seed", "8"]), &specs()).unwrap();
        assert_eq!(a.usize_or("seed", 1).unwrap(), 8);
        assert_eq!(a.usize_or("missing", 4).unwrap(), 4);
    }

    #[test]
    fn help_renders() {
        let h = render_help("spork", "hybrid scheduler", &specs());
        assert!(h.contains("simulate"));
        let ch = render_command_help("spork", &specs()[0]);
        assert!(ch.contains("--burstiness"));
    }
}
