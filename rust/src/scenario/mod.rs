//! Scenario subsystem: deterministic, seed-derived adversity for the
//! simulator — preemptible spot workers whose price follows a mean-
//! reverting (Ornstein–Uhlenbeck) process with daily periodicity, a
//! preemption hazard inversely correlated with that price (strikes
//! cluster when capacity is cheap and contended), and an independent
//! per-kind hardware-failure (MTTF) process.
//!
//! Everything a scenario will do to a run is materialized up front as a
//! [`FaultPlan`]: a time-sorted list of price ticks, preemption strikes,
//! and failures that is a *pure function* of `(config, seed_base, seed,
//! duration)`. The same cell therefore replays the identical fault
//! sequence regardless of which policy is being evaluated, how runs are
//! batched across `--jobs` threads, or what the policy does in response
//! — which is what makes scheduler comparisons under faults apples-to-
//! apples, and what the Python logic oracle (`tools/scenario_oracle.py`)
//! cross-validates bit-for-bit.
//!
//! The sim driver applies the plan (`Driver::attach_plan`): strikes kill
//! a live worker picked by the plan's uniform draw, drain its in-flight
//! requests, and re-offer them to the policy within a per-request retry
//! budget; spot-billed kinds pay their on-demand rate scaled by the
//! price-path integral. The §5.1 fitting searches stay fault-free — only
//! final evaluation runs see the plan — so fitted parameters measure the
//! policy, not the adversity.

mod plan;
mod price;

pub use plan::{Fault, FaultCounts, FaultPlan, PlannedFault};
pub use price::OuParams;

use crate::config::{WorkerKind, DEFAULT_RETRY_BUDGET};

/// Scenario knobs for one worker kind.
#[derive(Clone, Debug, PartialEq)]
pub struct KindScenario {
    /// Spot-billed (and preemptible): cost accrues as on-demand rate ×
    /// ∫ price(t) dt, and the preemption hazard below applies.
    pub spot: bool,
    /// Price process parameters (only sampled when `spot`).
    pub price: OuParams,
    /// Baseline preemption hazard in strikes/second at price == mu.
    pub preempt_rate: f64,
    /// Hazard exponent: actual hazard = `preempt_rate * (mu/price)^gamma`
    /// — low price ⇒ high reclaim pressure, like real spot markets.
    pub hazard_gamma: f64,
    /// Mean time to (independent hardware) failure, seconds. `INFINITY`
    /// disables the failure process.
    pub mttf: f64,
}

impl KindScenario {
    /// A kind the scenario leaves alone entirely.
    pub fn benign() -> Self {
        KindScenario {
            spot: false,
            price: OuParams::flat(),
            preempt_rate: 0.0,
            hazard_gamma: 0.0,
            mttf: f64::INFINITY,
        }
    }
}

/// A named adversity pack: per-kind spot/fault processes plus the retry
/// policy the driver enforces when a kill orphans in-flight requests.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioConfig {
    pub name: String,
    /// Per-kind knobs, indexed by [`WorkerKind::index`].
    pub kinds: [KindScenario; 2],
    /// Max re-dispatches per request: a request killed with `attempt ==
    /// retry_budget` is abandoned (counted as a deadline miss).
    pub retry_budget: u32,
    /// Price-process step, seconds (one OU step and one hazard window).
    pub price_dt: f64,
    /// Extra salt folded into the plan's seed root, so embedders can
    /// decorrelate plans from everything else derived from a seed pair.
    pub seed_salt: u64,
}

impl ScenarioConfig {
    /// No spot billing, no faults: plans are empty and runs are
    /// bit-identical to the pre-scenario engine (the parity pack).
    pub fn fault_free() -> Self {
        ScenarioConfig {
            name: "fault-free".into(),
            kinds: [KindScenario::benign(), KindScenario::benign()],
            retry_budget: DEFAULT_RETRY_BUDGET,
            price_dt: 1.0,
            seed_salt: 0,
        }
    }

    /// Spot FPGAs with gentle price motion, sparse preemptions (one per
    /// ~10 min at the mean price), and a 1-day FPGA MTTF.
    pub fn mild() -> Self {
        let mut fpga = KindScenario::benign();
        fpga.spot = true;
        fpga.price = OuParams {
            mu: 0.35,
            theta: 1.0 / 600.0,
            sigma: 0.006,
            daily_amp: 0.25,
            period: 86_400.0,
            floor: 0.05,
            init: 0.35,
        };
        fpga.preempt_rate = 1.0 / 600.0;
        fpga.hazard_gamma = 2.0;
        fpga.mttf = 86_400.0;
        ScenarioConfig {
            name: "mild".into(),
            kinds: [KindScenario::benign(), fpga],
            retry_budget: DEFAULT_RETRY_BUDGET,
            price_dt: 1.0,
            seed_salt: 0,
        }
    }

    /// Volatile cheap spot FPGAs under heavy reclaim pressure (≈ one
    /// strike per 10 s at the mean price, more when the price dips), a
    /// 1-hour FPGA MTTF, and CPUs that also fail (2-hour MTTF).
    pub fn severe() -> Self {
        let mut fpga = KindScenario::benign();
        fpga.spot = true;
        fpga.price = OuParams {
            mu: 0.30,
            theta: 1.0 / 300.0,
            sigma: 0.012,
            daily_amp: 0.35,
            period: 86_400.0,
            floor: 0.05,
            init: 0.30,
        };
        fpga.preempt_rate = 0.1;
        fpga.hazard_gamma = 3.0;
        fpga.mttf = 3_600.0;
        let mut cpu = KindScenario::benign();
        cpu.mttf = 7_200.0;
        ScenarioConfig {
            name: "severe".into(),
            kinds: [cpu, fpga],
            retry_budget: DEFAULT_RETRY_BUDGET,
            price_dt: 1.0,
            seed_salt: 0,
        }
    }

    /// Parse a pack name (CLI `--scenario` / sweep axis vocabulary).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "fault-free" | "none" => Some(Self::fault_free()),
            "mild" => Some(Self::mild()),
            "severe" => Some(Self::severe()),
            _ => None,
        }
    }

    /// The scenario packs experiments sweep over, mildest first.
    pub fn packs() -> Vec<ScenarioConfig> {
        vec![Self::fault_free(), Self::mild(), Self::severe()]
    }

    /// Whether any kind can produce a fault or a spot bill (false only
    /// for the parity pack).
    pub fn is_adverse(&self) -> bool {
        self.kinds.iter().any(|k| {
            k.spot || k.preempt_rate > 0.0 || (k.mttf.is_finite() && k.mttf > 0.0)
        })
    }

    /// The scenario knobs for `kind`.
    pub fn kind(&self, kind: WorkerKind) -> &KindScenario {
        &self.kinds[kind.index()]
    }

    /// Validate the pack before a plan is built or a retry budget is
    /// shared with the serve recovery layer: every rate finite and ≥ 0,
    /// the price step strictly positive, and the retry budget within
    /// [`crate::config::MAX_RETRY_BUDGET`] — the single check both the
    /// sim's re-dispatch path and serve recovery sit behind, so the two
    /// can never drift on how many attempts a request gets.
    pub fn validate(&self) -> Result<(), String> {
        if self.retry_budget > crate::config::MAX_RETRY_BUDGET {
            return Err(format!(
                "scenario '{}': retry_budget {} exceeds the sanity cap {}",
                self.name,
                self.retry_budget,
                crate::config::MAX_RETRY_BUDGET
            ));
        }
        if !(self.price_dt.is_finite() && self.price_dt > 0.0) {
            return Err(format!(
                "scenario '{}': price_dt must be finite and > 0 (got {})",
                self.name, self.price_dt
            ));
        }
        for (i, k) in self.kinds.iter().enumerate() {
            let kind = if i == 0 { "cpu" } else { "fpga" };
            if !(k.preempt_rate.is_finite() && k.preempt_rate >= 0.0) {
                return Err(format!(
                    "scenario '{}' ({kind}): preempt_rate must be finite and >= 0 (got {})",
                    self.name, k.preempt_rate
                ));
            }
            if !k.hazard_gamma.is_finite() {
                return Err(format!(
                    "scenario '{}' ({kind}): hazard_gamma must be finite (got {})",
                    self.name, k.hazard_gamma
                ));
            }
            // INFINITY disables the failure process; NaN and non-positive
            // values are configuration errors.
            if k.mttf.is_nan() || k.mttf <= 0.0 {
                return Err(format!(
                    "scenario '{}' ({kind}): mttf must be > 0 (INFINITY disables; got {})",
                    self.name, k.mttf
                ));
            }
            if k.spot {
                let p = &k.price;
                for (name, v) in [
                    ("mu", p.mu),
                    ("theta", p.theta),
                    ("sigma", p.sigma),
                    ("daily_amp", p.daily_amp),
                    ("period", p.period),
                    ("floor", p.floor),
                    ("init", p.init),
                ] {
                    if !v.is_finite() {
                        return Err(format!(
                            "scenario '{}' ({kind}): price.{name} must be finite (got {v})",
                            self.name
                        ));
                    }
                }
                if p.floor <= 0.0 {
                    return Err(format!(
                        "scenario '{}' ({kind}): price.floor must be > 0 (got {})",
                        self.name, p.floor
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_names_round_trip() {
        for pack in ScenarioConfig::packs() {
            let parsed = ScenarioConfig::from_name(&pack.name).expect("pack parses");
            assert_eq!(parsed, pack);
        }
        assert_eq!(
            ScenarioConfig::from_name("none"),
            Some(ScenarioConfig::fault_free())
        );
        assert_eq!(ScenarioConfig::from_name("bogus"), None);
    }

    #[test]
    fn adversity_classification() {
        assert!(!ScenarioConfig::fault_free().is_adverse());
        assert!(ScenarioConfig::mild().is_adverse());
        assert!(ScenarioConfig::severe().is_adverse());
    }

    #[test]
    fn builtin_packs_validate_and_share_one_retry_budget() {
        for pack in ScenarioConfig::packs() {
            pack.validate().expect("built-in pack must validate");
            assert_eq!(pack.retry_budget, crate::config::DEFAULT_RETRY_BUDGET);
        }
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut s = ScenarioConfig::severe();
        s.retry_budget = crate::config::MAX_RETRY_BUDGET + 1;
        assert!(s.validate().is_err());

        let mut s = ScenarioConfig::severe();
        s.price_dt = 0.0;
        assert!(s.validate().is_err());

        let mut s = ScenarioConfig::severe();
        s.kinds[0].mttf = f64::NAN;
        assert!(s.validate().is_err());

        let mut s = ScenarioConfig::severe();
        s.kinds[1].preempt_rate = -1.0;
        assert!(s.validate().is_err());

        let mut s = ScenarioConfig::severe();
        s.kinds[1].price.floor = 0.0;
        assert!(s.validate().is_err());
    }
}
