//! Fault plans: the materialized, replayable fault sequence of one
//! `(scenario, seed_base, seed, duration)` cell.
//!
//! # Determinism contract
//!
//! [`FaultPlan::build`] is a pure function. Each `(kind, channel)` pair
//! draws from its own [`Rng::for_stream`] stream, so the price walk, the
//! strike process, and the failure process never share a generator — and
//! adding draws to one can never shift another. The derivation (mirrored
//! exactly by `tools/scenario_oracle.py`, which re-implements the RNG in
//! Python and must agree bit-for-bit):
//!
//! ```text
//! root   = seed_base ^ SCENARIO_SALT ^ cfg.seed_salt
//! stream = seed·8 + kind_index·3 + channel     (all wrapping)
//! rng    = Rng::for_stream(root, stream)
//! channel 0 = price walk, 1 = preemption strikes, 2 = failures
//! ```
//!
//! Per price step `[t, t+dt)` for a spot kind: first the OU update (one
//! `normal` draw; skipped at t=0, where the price is `init`), then the
//! hazard Bernoulli (one `f64` draw via `chance`, *always* consumed);
//! on a strike, two more `f64` draws (offset within the step, victim).
//! Failures are an independent exponential-gap process: alternating
//! `exp(1/mttf)` and `f64` (victim) draws while within the duration.

use super::price::OuParams;
use super::ScenarioConfig;
use crate::config::WorkerKind;
use crate::util::rng::Rng;

/// Salt decorrelating scenario streams from every other consumer of the
/// same `(seed_base, seed)` pair (sweep cells, synthetic traces).
pub const SCENARIO_SALT: u64 = 0x5CE7_A210_FA57_0B1E;

/// One planned fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// The spot price of `kind` steps to `price`.
    PriceTick { kind: WorkerKind, price: f64 },
    /// A spot preemption strike against `kind`; the driver picks victim
    /// `floor(victim_draw · n)` over the kind's live accepting workers.
    Preemption { kind: WorkerKind, victim_draw: f64 },
    /// An independent hardware failure of one worker of `kind`.
    Failure { kind: WorkerKind, victim_draw: f64 },
}

/// A fault with its injection time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlannedFault {
    pub time: f64,
    pub fault: Fault,
}

/// The full, time-sorted fault sequence of one scenario cell.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<PlannedFault>,
}

/// `(price_ticks, preemptions, failures)`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub price_ticks: u64,
    pub preemptions: u64,
    pub failures: u64,
}

impl FaultPlan {
    /// Build the plan for one cell. Pure: same inputs ⇒ identical plan,
    /// independent of policy, thread count, or call site.
    pub fn build(cfg: &ScenarioConfig, seed_base: u64, seed: u64, duration: f64) -> FaultPlan {
        let mut faults = Vec::new();
        if !duration.is_finite() || duration <= 0.0 {
            return FaultPlan { faults };
        }
        let root = seed_base ^ SCENARIO_SALT ^ cfg.seed_salt;
        let stream = |k: usize, ch: u64| {
            seed.wrapping_mul(8)
                .wrapping_add((k as u64).wrapping_mul(3))
                .wrapping_add(ch)
        };
        for (k, ks) in cfg.kinds.iter().enumerate() {
            let kind = WorkerKind::ALL[k];
            if ks.spot {
                let mut price_rng = Rng::for_stream(root, stream(k, 0));
                let mut strike_rng = Rng::for_stream(root, stream(k, 1));
                let dt = cfg.price_dt;
                let mut x = ks.price.init.max(ks.price.floor);
                let mut i: u64 = 0;
                loop {
                    let t = i as f64 * dt;
                    if t >= duration {
                        break;
                    }
                    if i > 0 {
                        // OU update lands the price for [t, t+dt); the
                        // initial price is set by the driver at attach.
                        x = ks.price.step(x, t, dt, price_rng.normal(0.0, 1.0));
                        faults.push(PlannedFault {
                            time: t,
                            fault: Fault::PriceTick { kind, price: x },
                        });
                    }
                    if ks.preempt_rate > 0.0 {
                        let hazard = ks.preempt_rate * (ks.price.mu / x).powf(ks.hazard_gamma);
                        let p = (hazard * dt).min(1.0);
                        // `chance` always consumes one draw, so the strike
                        // stream is step-aligned regardless of outcomes.
                        if strike_rng.chance(p) {
                            let offset = strike_rng.f64();
                            let victim_draw = strike_rng.f64();
                            faults.push(PlannedFault {
                                time: t + offset * dt,
                                fault: Fault::Preemption { kind, victim_draw },
                            });
                        }
                    }
                    i += 1;
                }
            }
            if ks.mttf.is_finite() && ks.mttf > 0.0 {
                let mut fail_rng = Rng::for_stream(root, stream(k, 2));
                let mut t = fail_rng.exp(1.0 / ks.mttf);
                while t < duration {
                    let victim_draw = fail_rng.f64();
                    faults.push(PlannedFault {
                        time: t,
                        fault: Fault::Failure { kind, victim_draw },
                    });
                    t += fail_rng.exp(1.0 / ks.mttf);
                }
            }
        }
        // Stable sort: equal-time faults keep kind-major generation order.
        faults.sort_by(|a, b| a.time.total_cmp(&b.time));
        FaultPlan { faults }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn counts(&self) -> FaultCounts {
        let mut c = FaultCounts::default();
        for pf in &self.faults {
            match pf.fault {
                Fault::PriceTick { .. } => c.price_ticks += 1,
                Fault::Preemption { .. } => c.preemptions += 1,
                Fault::Failure { .. } => c.failures += 1,
            }
        }
        c
    }

    /// Order-sensitive content digest — the value the Python oracle
    /// recomputes from scratch to cross-validate the generator. Mix:
    /// `h = (rotl(h,7) ^ v) * 0x9E3779B97F4A7C15` folded over, per fault,
    /// the time bits, the `tag·4 + kind_index` discriminant (tag 1/2/3 =
    /// tick/preemption/failure), and the payload bits.
    pub fn digest(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            (h.rotate_left(7) ^ v).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        }
        let mut h = 0u64;
        for pf in &self.faults {
            let (tag, kind, payload) = match pf.fault {
                Fault::PriceTick { kind, price } => (1u64, kind, price),
                Fault::Preemption { kind, victim_draw } => (2, kind, victim_draw),
                Fault::Failure { kind, victim_draw } => (3, kind, victim_draw),
            };
            h = mix(h, pf.time.to_bits());
            h = mix(h, tag * 4 + kind.index() as u64);
            h = mix(h, payload.to_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioConfig;

    #[test]
    fn fault_free_plans_nothing() {
        let plan = FaultPlan::build(&ScenarioConfig::fault_free(), 1, 0, 3600.0);
        assert!(plan.is_empty());
        assert_eq!(plan.digest(), 0);
    }

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        let cfg = ScenarioConfig::severe();
        let a = FaultPlan::build(&cfg, 1, 0, 600.0);
        let b = FaultPlan::build(&cfg, 1, 0, 600.0);
        assert_eq!(a, b, "same cell ⇒ identical plan");
        let c = FaultPlan::build(&cfg, 1, 1, 600.0);
        assert_ne!(a.digest(), c.digest(), "seed must move the plan");
        let d = FaultPlan::build(&cfg, 2, 0, 600.0);
        assert_ne!(a.digest(), d.digest(), "seed_base must move the plan");
    }

    #[test]
    fn plans_are_sorted_and_floored() {
        let cfg = ScenarioConfig::severe();
        let plan = FaultPlan::build(&cfg, 1, 0, 600.0);
        let floor = cfg.kinds[1].price.floor;
        for w in plan.faults.windows(2) {
            assert!(w[0].time <= w[1].time, "plan must be time-sorted");
        }
        for pf in &plan.faults {
            assert!(pf.time >= 0.0 && pf.time.is_finite());
            if let Fault::PriceTick { price, .. } = pf.fault {
                assert!(price >= floor, "price {price} under floor {floor}");
            }
        }
    }

    #[test]
    fn severe_pack_actually_strikes() {
        // The vacuity tripwire's static counterpart: over a CI-smoke-sized
        // window the severe pack must plan preemptions and price motion.
        let plan = FaultPlan::build(&ScenarioConfig::severe(), 1, 0, 50.0);
        let c = plan.counts();
        assert!(c.preemptions > 0, "severe/50s planned no strikes: {c:?}");
        assert_eq!(c.price_ticks, 49, "one tick per dt after t=0");
    }

    #[test]
    fn mild_pack_is_sparser_than_severe() {
        let mild = FaultPlan::build(&ScenarioConfig::mild(), 1, 0, 3600.0).counts();
        let severe = FaultPlan::build(&ScenarioConfig::severe(), 1, 0, 3600.0).counts();
        assert!(
            severe.preemptions > mild.preemptions,
            "severe {severe:?} vs mild {mild:?}"
        );
    }

    #[test]
    fn degenerate_durations_plan_nothing() {
        let cfg = ScenarioConfig::severe();
        assert!(FaultPlan::build(&cfg, 1, 0, 0.0).is_empty());
        assert!(FaultPlan::build(&cfg, 1, 0, -5.0).is_empty());
        assert!(FaultPlan::build(&cfg, 1, 0, f64::NAN).is_empty());
        assert!(FaultPlan::build(&cfg, 1, 0, f64::INFINITY).is_empty());
    }
}
