//! The spot-price process: a mean-reverting Ornstein–Uhlenbeck walk
//! whose long-run mean swings with a daily period — the standard model
//! for spot-market price series (cheap at night, contended by day), with
//! a hard floor as real spot markets have.

/// Parameters of the discretized OU price walk
/// `x += theta·(mu_t − x)·dt + sigma·√dt·N(0,1)`, where the time-varying
/// mean is `mu_t = mu·(1 + daily_amp·sin(2π·t/period))` and the result
/// is clamped at `floor`. Prices are multipliers on a kind's on-demand
/// cost rate (1.0 = on-demand parity; spot typically sits well below).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OuParams {
    /// Long-run mean price multiplier.
    pub mu: f64,
    /// Mean-reversion rate per second (1/theta is the relaxation time).
    pub theta: f64,
    /// Diffusion scale per √second.
    pub sigma: f64,
    /// Relative amplitude of the daily swing of the mean.
    pub daily_amp: f64,
    /// Period of the mean's oscillation, seconds (a day).
    pub period: f64,
    /// Hard price floor (spot markets never quote zero).
    pub floor: f64,
    /// Price at t = 0.
    pub init: f64,
}

impl OuParams {
    /// A constant price of 1.0 — the parameters of a kind the scenario
    /// never samples (non-spot), kept valid so accidental sampling is
    /// harmless rather than NaN-producing.
    pub fn flat() -> Self {
        OuParams {
            mu: 1.0,
            theta: 0.0,
            sigma: 0.0,
            daily_amp: 0.0,
            period: 86_400.0,
            floor: 1.0,
            init: 1.0,
        }
    }

    /// The time-varying mean `mu_t` at time `t`.
    pub fn mean_at(&self, t: f64) -> f64 {
        self.mu * (1.0 + self.daily_amp * (2.0 * std::f64::consts::PI * t / self.period).sin())
    }

    /// One discrete OU step from `x` over `[t, t+dt)` given a standard
    /// normal draw `z`; clamped at the floor.
    pub fn step(&self, x: f64, t: f64, dt: f64, z: f64) -> f64 {
        let next = x + self.theta * (self.mean_at(t) - x) * dt + self.sigma * dt.sqrt() * z;
        next.max(self.floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn flat_params_never_move() {
        let p = OuParams::flat();
        let mut x = p.init;
        for i in 0..100 {
            x = p.step(x, i as f64, 1.0, 0.7);
            assert_eq!(x, 1.0);
        }
    }

    #[test]
    fn walk_reverts_to_the_mean_and_respects_floor() {
        // Noise-free walk from far above the mean decays toward mu; a
        // walk driven hard downward pins at the floor.
        let p = OuParams {
            mu: 0.3,
            theta: 0.1,
            sigma: 0.0,
            daily_amp: 0.0,
            period: 86_400.0,
            floor: 0.05,
            init: 2.0,
        };
        let mut x = p.init;
        for i in 0..200 {
            x = p.step(x, i as f64, 1.0, 0.0);
        }
        assert!((x - 0.3).abs() < 1e-6, "x = {x}");
        let mut p2 = p;
        p2.sigma = 10.0;
        let down = p2.step(0.3, 0.0, 1.0, -5.0);
        assert_eq!(down, p2.floor);
    }

    #[test]
    fn long_run_sample_mean_tracks_mu() {
        // Statistical sanity (fixed seed, no flake): the stationary mean
        // of the sampled walk sits near mu.
        let p = OuParams {
            mu: 0.35,
            theta: 0.05,
            sigma: 0.01,
            daily_amp: 0.0,
            period: 86_400.0,
            floor: 0.05,
            init: 0.35,
        };
        let mut rng = Rng::for_stream(42, 0);
        let mut x = p.init;
        let mut sum = 0.0;
        let n = 20_000;
        for i in 0..n {
            x = p.step(x, i as f64, 1.0, rng.normal(0.0, 1.0));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - p.mu).abs() < 0.05, "mean = {mean}");
    }
}
