//! Worker parameterization — paper Table 6 (defaults non-italicized there):
//!
//! |                   | CPU worker | FPGA worker           |
//! |-------------------|------------|-----------------------|
//! | Spin-up latency   | 5 ms       | 1 s, **10 s**, 60 s, 100 s |
//! | Spin-down latency | 5 ms       | 100 ms                |
//! | Relative speedup  | 1x         | 1x, **2x**, 4x        |
//! | Busy power        | 150 W      | 25 W, **50 W**, 100 W |
//! | Idle power        | 10/**30**/50 W | 10/**20**/30 W    |
//! | Prorated cost     | $0.668/hr  | $0.982/hr             |
//!
//! Workers draw **busy power during spin up and spin down** (§5.1).

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkerKind {
    Cpu,
    Fpga,
}

impl WorkerKind {
    /// The full worker-class roster, in canonical (pool index) order.
    /// Every "for each kind" loop should iterate this instead of a
    /// hardcoded array so a third platform lands in one place.
    pub const ALL: [WorkerKind; 2] = [WorkerKind::Cpu, WorkerKind::Fpga];

    /// The roster in dispatch-preference order (Alg 3 tries the
    /// energy-efficient kind first). Distinct from [`WorkerKind::ALL`]
    /// because here the order is semantic, not just an enumeration.
    pub const EFFICIENT_FIRST: [WorkerKind; 2] = [WorkerKind::Fpga, WorkerKind::Cpu];

    pub fn name(&self) -> &'static str {
        match self {
            WorkerKind::Cpu => "cpu",
            WorkerKind::Fpga => "fpga",
        }
    }

    /// Index of this kind in [`WorkerKind::ALL`] (stable across the repo:
    /// per-kind state arrays are `[T; WorkerKind::ALL.len()]`).
    #[inline]
    pub fn index(&self) -> usize {
        match self {
            WorkerKind::Cpu => 0,
            WorkerKind::Fpga => 1,
        }
    }
}

/// Physical/economic parameters of one worker class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerParams {
    /// Spin-up latency A_w (seconds).
    pub spin_up: f64,
    /// Spin-down latency (seconds).
    pub spin_down: f64,
    /// Processing speedup S relative to a CPU worker (CPU = 1).
    pub speedup: f64,
    /// Busy power B_w (watts). Also drawn during spin up/down.
    pub busy_power: f64,
    /// Idle power I_w (watts).
    pub idle_power: f64,
    /// Occupancy cost C_w ($/hour while allocated).
    pub cost_per_hour: f64,
}

impl WorkerParams {
    pub fn cpu_default() -> Self {
        Self {
            spin_up: 0.005,
            spin_down: 0.005,
            speedup: 1.0,
            busy_power: 150.0,
            idle_power: 30.0,
            cost_per_hour: 0.668,
        }
    }

    pub fn fpga_default() -> Self {
        Self {
            spin_up: 10.0,
            spin_down: 0.100,
            speedup: 2.0,
            busy_power: 50.0,
            idle_power: 20.0,
            cost_per_hour: 0.982,
        }
    }

    /// Energy to spin up one worker (busy power over the spin-up window).
    /// Paper §3.2: 0.75 J for CPUs, 500 J for FPGAs at defaults.
    pub fn spin_up_energy(&self) -> f64 {
        self.spin_up * self.busy_power
    }

    /// Energy to spin down one worker.
    pub fn spin_down_energy(&self) -> f64 {
        self.spin_down * self.busy_power
    }

    /// Cost per second while allocated.
    pub fn cost_per_sec(&self) -> f64 {
        self.cost_per_hour / 3600.0
    }

    /// Service time on this worker for a request of `size` CPU-seconds.
    pub fn service_time(&self, size: f64) -> f64 {
        size / self.speedup
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spin_up", Json::Num(self.spin_up)),
            ("spin_down", Json::Num(self.spin_down)),
            ("speedup", Json::Num(self.speedup)),
            ("busy_power", Json::Num(self.busy_power)),
            ("idle_power", Json::Num(self.idle_power)),
            ("cost_per_hour", Json::Num(self.cost_per_hour)),
        ])
    }

    pub fn from_json(j: &Json, base: WorkerParams) -> anyhow::Result<Self> {
        let p = Self {
            spin_up: j.f64_or("spin_up", base.spin_up),
            spin_down: j.f64_or("spin_down", base.spin_down),
            speedup: j.f64_or("speedup", base.speedup),
            busy_power: j.f64_or("busy_power", base.busy_power),
            idle_power: j.f64_or("idle_power", base.idle_power),
            cost_per_hour: j.f64_or("cost_per_hour", base.cost_per_hour),
        };
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.spin_up.is_finite() && self.spin_up >= 0.0,
            "spin_up must be finite and >= 0"
        );
        anyhow::ensure!(
            self.spin_down.is_finite() && self.spin_down >= 0.0,
            "spin_down must be finite and >= 0"
        );
        anyhow::ensure!(
            self.speedup.is_finite() && self.speedup > 0.0,
            "speedup must be finite and > 0"
        );
        // Strictly positive: busy_power is the denominator of the energy
        // advantage and the per-joule efficiency metrics — 0 W "validates"
        // into an infinite advantage.
        anyhow::ensure!(
            self.busy_power.is_finite() && self.busy_power > 0.0,
            "busy_power must be finite and > 0"
        );
        anyhow::ensure!(
            self.idle_power.is_finite() && self.idle_power >= 0.0,
            "idle_power must be finite and >= 0"
        );
        anyhow::ensure!(
            self.idle_power <= self.busy_power,
            "idle_power must not exceed busy_power"
        );
        anyhow::ensure!(
            self.cost_per_hour.is_finite() && self.cost_per_hour >= 0.0,
            "cost_per_hour must be finite and >= 0"
        );
        Ok(())
    }
}

/// The two worker classes of the hybrid platform.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlatformConfig {
    pub cpu: WorkerParams,
    pub fpga: WorkerParams,
}

impl PlatformConfig {
    pub fn paper_default() -> Self {
        Self {
            cpu: WorkerParams::cpu_default(),
            fpga: WorkerParams::fpga_default(),
        }
    }

    pub fn params(&self, kind: WorkerKind) -> &WorkerParams {
        match kind {
            WorkerKind::Cpu => &self.cpu,
            WorkerKind::Fpga => &self.fpga,
        }
    }

    /// FPGA busy-energy efficiency over CPU for the same work:
    /// (B_c * 1) / (B_f / S). Paper §3.2 defaults: 150/(50/2) = 6x.
    ///
    /// Degenerate platforms (zero or non-finite busy power / speedup —
    /// rejected by [`WorkerParams::validate`], but this is also called on
    /// hand-built configs) clamp to 1.0 ("no advantage") instead of
    /// returning an infinite or NaN ratio that would poison downstream
    /// breakeven math.
    pub fn fpga_energy_advantage(&self) -> f64 {
        let per_work_fpga = self.fpga.busy_power / self.fpga.speedup;
        if !per_work_fpga.is_finite() || per_work_fpga <= 0.0 || !self.cpu.busy_power.is_finite()
        {
            return 1.0;
        }
        let adv = self.cpu.busy_power / per_work_fpga;
        if adv.is_finite() {
            adv
        } else {
            1.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cpu", self.cpu.to_json()),
            ("fpga", self.fpga.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let base = Self::paper_default();
        Ok(Self {
            cpu: match j.get("cpu") {
                Some(c) => WorkerParams::from_json(c, base.cpu)?,
                None => base.cpu,
            },
            fpga: match j.get("fpga") {
                Some(f) => WorkerParams::from_json(f, base.fpga)?,
                None => base.fpga,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_energy_advantage_is_6x() {
        let p = PlatformConfig::paper_default();
        assert!((p.fpga_energy_advantage() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn service_time_uses_speedup() {
        let f = WorkerParams::fpga_default();
        assert!((f.service_time(0.010) - 0.005).abs() < 1e-12);
        let c = WorkerParams::cpu_default();
        assert!((c.service_time(0.010) - 0.010).abs() < 1e-12);
    }

    #[test]
    fn cost_per_sec() {
        let c = WorkerParams::cpu_default();
        assert!((c.cost_per_sec() * 3600.0 - 0.668).abs() < 1e-9);
    }

    #[test]
    fn validation_rejects_bad_params() {
        let mut p = WorkerParams::cpu_default();
        p.speedup = 0.0;
        assert!(p.validate().is_err());
        let mut p = WorkerParams::cpu_default();
        p.idle_power = 200.0; // > busy
        assert!(p.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_and_nonfinite_power() {
        // busy_power: 0.0 used to validate and yield an infinite
        // energy advantage.
        let mut p = WorkerParams::fpga_default();
        p.busy_power = 0.0;
        p.idle_power = 0.0;
        assert!(p.validate().is_err());
        for bad in [f64::NAN, f64::INFINITY, -1.0] {
            let mut p = WorkerParams::fpga_default();
            p.busy_power = bad;
            assert!(p.validate().is_err(), "busy_power {bad}");
            let mut p = WorkerParams::cpu_default();
            p.spin_up = bad;
            assert!(p.validate().is_err(), "spin_up {bad}");
            let mut p = WorkerParams::cpu_default();
            p.cost_per_hour = bad;
            assert!(p.validate().is_err(), "cost_per_hour {bad}");
        }
    }

    #[test]
    fn energy_advantage_guards_degenerate_platforms() {
        let mut p = PlatformConfig::paper_default();
        p.fpga.busy_power = 0.0;
        assert_eq!(p.fpga_energy_advantage(), 1.0);
        let mut p = PlatformConfig::paper_default();
        p.fpga.busy_power = f64::NAN;
        assert_eq!(p.fpga_energy_advantage(), 1.0);
        let mut p = PlatformConfig::paper_default();
        p.cpu.busy_power = f64::INFINITY;
        assert_eq!(p.fpga_energy_advantage(), 1.0);
        // Sane platforms are untouched.
        let p = PlatformConfig::paper_default();
        assert!((p.fpga_energy_advantage() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn roster_consts_cover_both_kinds() {
        assert_eq!(WorkerKind::ALL.len(), WorkerKind::EFFICIENT_FIRST.len());
        for kind in WorkerKind::ALL {
            assert!(WorkerKind::EFFICIENT_FIRST.contains(&kind));
            assert_eq!(WorkerKind::ALL[kind.index()], kind);
        }
    }

    #[test]
    fn from_json_partial_overrides() {
        let j = Json::parse(r#"{"fpga": {"spin_up": 60}}"#).unwrap();
        let p = PlatformConfig::from_json(&j).unwrap();
        assert_eq!(p.fpga.spin_up, 60.0);
        assert_eq!(p.fpga.busy_power, 50.0); // default retained
        assert_eq!(p.cpu, WorkerParams::cpu_default());
    }

    #[test]
    fn kind_names() {
        assert_eq!(WorkerKind::Cpu.name(), "cpu");
        assert_eq!(WorkerKind::Fpga.name(), "fpga");
    }
}
