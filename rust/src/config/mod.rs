//! Configuration system: worker parameters (paper Table 6), scheduler
//! selection, and experiment descriptions. Configs have paper-default
//! constructors and can be loaded from / saved to JSON files.

mod workers;

pub use workers::{PlatformConfig, WorkerKind, WorkerParams};

use crate::util::json::Json;

/// The one retry budget every layer shares: max re-dispatches per request
/// after its worker is preempted or fails. The scenario packs embed it
/// (`ScenarioConfig::retry_budget`), the sim driver enforces it in
/// `apply_fault`, and serve recovery derives its deadline-aware retry
/// window from the *same* attached pack — centralizing the constant here
/// is what keeps sim re-dispatch and serve recovery from drifting.
pub const DEFAULT_RETRY_BUDGET: u32 = 3;

/// Sanity cap for `ScenarioConfig::retry_budget` (validated in
/// `ScenarioConfig::validate`): budgets beyond this are configuration
/// errors, not resilience — each retry re-enters the dispatch path, so an
/// unbounded budget can amplify a single fault into a dispatch storm.
pub const MAX_RETRY_BUDGET: u32 = 64;

/// Which scheduler to run — §5.1 "Baselines" plus the Spork variants.
#[derive(Clone, Debug, PartialEq)]
pub enum SchedulerKind {
    /// CPU-only reactive scheduler (serverless/AutoScale style).
    CpuDynamic,
    /// FPGA-only, statically provisioned for peak load (perfect knowledge).
    FpgaStatic,
    /// FPGA-only reactive scheduler with fixed excess headroom.
    FpgaDynamic,
    /// Idealized MArk: cost-optimized hybrid, perfect 2-interval rate
    /// predictions, round-robin dispatch.
    MarkIdeal,
    /// Spork with objective weights (w_energy, w_cost). (1,0)=SporkE,
    /// (0,1)=SporkC, (0.5,0.5)=SporkB.
    Spork {
        w_energy: f64,
        w_cost: f64,
        /// Perfect next-interval worker-count predictions (SporkE-ideal /
        /// SporkC-ideal), ignoring spin-up overhead accounting (§5.1).
        ideal: bool,
    },
    /// Tessera-style greedy spot baseline: run everything on the cheap
    /// preemptible kind, re-dispatching preempted work back onto it.
    GreedySpot,
    /// Tessera-style fallback baseline: prefer the spot kind, but route
    /// retries (and spot-infeasible requests) to on-demand CPUs.
    OndemandFallback,
    /// Spork (energy objective) wrapped with an on-demand retry fallback:
    /// re-dispatched requests go straight to CPUs instead of re-entering
    /// Alg-3 dispatch.
    SporkFallback,
}

impl SchedulerKind {
    pub fn spork_e() -> Self {
        SchedulerKind::Spork { w_energy: 1.0, w_cost: 0.0, ideal: false }
    }
    pub fn spork_c() -> Self {
        SchedulerKind::Spork { w_energy: 0.0, w_cost: 1.0, ideal: false }
    }
    pub fn spork_b() -> Self {
        SchedulerKind::Spork { w_energy: 0.5, w_cost: 0.5, ideal: false }
    }
    pub fn spork_e_ideal() -> Self {
        SchedulerKind::Spork { w_energy: 1.0, w_cost: 0.0, ideal: true }
    }
    pub fn spork_c_ideal() -> Self {
        SchedulerKind::Spork { w_energy: 0.0, w_cost: 1.0, ideal: true }
    }

    /// Parse the names used throughout the CLI and experiment harness.
    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "cpu-dynamic" => SchedulerKind::CpuDynamic,
            "fpga-static" => SchedulerKind::FpgaStatic,
            "fpga-dynamic" => SchedulerKind::FpgaDynamic,
            "mark-ideal" => SchedulerKind::MarkIdeal,
            "spork-e" => Self::spork_e(),
            "spork-c" => Self::spork_c(),
            "spork-b" => Self::spork_b(),
            "spork-e-ideal" => Self::spork_e_ideal(),
            "spork-c-ideal" => Self::spork_c_ideal(),
            "greedy-spot" => SchedulerKind::GreedySpot,
            "ondemand-fallback" => SchedulerKind::OndemandFallback,
            "spork-fallback" => SchedulerKind::SporkFallback,
            _ => return None,
        })
    }

    pub fn name(&self) -> String {
        match self {
            SchedulerKind::CpuDynamic => "cpu-dynamic".into(),
            SchedulerKind::FpgaStatic => "fpga-static".into(),
            SchedulerKind::FpgaDynamic => "fpga-dynamic".into(),
            SchedulerKind::MarkIdeal => "mark-ideal".into(),
            SchedulerKind::Spork { w_energy, w_cost, ideal } => {
                let base = if *w_energy > 0.0 && *w_cost > 0.0 {
                    "spork-b"
                } else if *w_cost > 0.0 {
                    "spork-c"
                } else {
                    "spork-e"
                };
                if *ideal {
                    format!("{base}-ideal")
                } else {
                    base.into()
                }
            }
            SchedulerKind::GreedySpot => "greedy-spot".into(),
            SchedulerKind::OndemandFallback => "ondemand-fallback".into(),
            SchedulerKind::SporkFallback => "spork-fallback".into(),
        }
    }

    /// Display name matching the paper's tables.
    pub fn display(&self) -> String {
        match self.name().as_str() {
            "cpu-dynamic" => "CPU-dynamic".into(),
            "fpga-static" => "FPGA-static".into(),
            "fpga-dynamic" => "FPGA-dynamic".into(),
            "mark-ideal" => "MArk-ideal".into(),
            "spork-e" => "SporkE".into(),
            "spork-c" => "SporkC".into(),
            "spork-b" => "SporkB".into(),
            "spork-e-ideal" => "SporkE-ideal".into(),
            "spork-c-ideal" => "SporkC-ideal".into(),
            "greedy-spot" => "GreedySpot".into(),
            "ondemand-fallback" => "OnDemandFallback".into(),
            "spork-fallback" => "SporkFallback".into(),
            other => other.into(),
        }
    }

    /// The full scheduler roster of Table 8.
    pub fn table8_roster() -> Vec<SchedulerKind> {
        vec![
            SchedulerKind::CpuDynamic,
            SchedulerKind::FpgaStatic,
            SchedulerKind::FpgaDynamic,
            SchedulerKind::MarkIdeal,
            Self::spork_c(),
            Self::spork_b(),
            Self::spork_e(),
            Self::spork_c_ideal(),
            Self::spork_e_ideal(),
        ]
    }

    /// The roster the scenario experiments compare: the tessera-style
    /// spot baselines, the fallback-wrapped Spork, and two Table-8
    /// members for reference. Kept out of [`Self::table8_roster`] so the
    /// paper tables stay exactly the paper's.
    pub fn scenario_roster() -> Vec<SchedulerKind> {
        vec![
            SchedulerKind::CpuDynamic,
            Self::spork_e(),
            SchedulerKind::GreedySpot,
            SchedulerKind::OndemandFallback,
            SchedulerKind::SporkFallback,
        ]
    }
}

/// Request dispatch policy (paper Table 9 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// MArk-style round robin [93].
    RoundRobin,
    /// AutoScale index packing [27]: busiest-first regardless of kind.
    IndexPacking,
    /// Spork's efficient-first (Alg 3): FPGA before CPU, then busiest-first.
    EfficientFirst,
}

impl DispatchPolicy {
    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "round-robin" => DispatchPolicy::RoundRobin,
            "index-packing" => DispatchPolicy::IndexPacking,
            "efficient-first" => DispatchPolicy::EfficientFirst,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::IndexPacking => "index-packing",
            DispatchPolicy::EfficientFirst => "efficient-first",
        }
    }
}

/// Simulation-wide knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub platform: PlatformConfig,
    /// Scheduling interval T_s (s). Paper: equals the FPGA spin-up latency.
    pub interval: f64,
    /// Idle duration before a worker is reclaimed (§5.1: "as long as the
    /// allocation duration"), per worker kind.
    pub cpu_idle_timeout: f64,
    pub fpga_idle_timeout: f64,
    /// Deadline multiplier over request size (paper: 10x).
    pub deadline_factor: f64,
    /// Optional cap on workers (paper assumes abundance; None = unbounded).
    pub max_cpus: Option<u32>,
    pub max_fpgas: Option<u32>,
    /// §4.5 future-work extension: deadline-aware FPGA allocation (ablation
    /// flag; off reproduces the paper).
    pub deadline_aware: bool,
}

impl SimConfig {
    pub fn paper_default() -> Self {
        let platform = PlatformConfig::paper_default();
        Self::from_platform(platform)
    }

    /// Derive interval/timeouts from platform parameters the way the paper
    /// does: T_s = A_f, idle timeout = allocation duration.
    pub fn from_platform(platform: PlatformConfig) -> Self {
        let interval = platform.fpga.spin_up;
        Self {
            cpu_idle_timeout: platform.cpu.spin_up.max(0.005),
            fpga_idle_timeout: interval,
            interval,
            platform,
            deadline_factor: 10.0,
            max_cpus: None,
            max_fpgas: None,
            deadline_aware: false,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("platform", self.platform.to_json()),
            ("interval", Json::Num(self.interval)),
            ("cpu_idle_timeout", Json::Num(self.cpu_idle_timeout)),
            ("fpga_idle_timeout", Json::Num(self.fpga_idle_timeout)),
            ("deadline_factor", Json::Num(self.deadline_factor)),
            (
                "max_cpus",
                self.max_cpus.map_or(Json::Null, |v| Json::Num(v as f64)),
            ),
            (
                "max_fpgas",
                self.max_fpgas.map_or(Json::Null, |v| Json::Num(v as f64)),
            ),
            ("deadline_aware", Json::Bool(self.deadline_aware)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let platform = match j.get("platform") {
            Some(p) => PlatformConfig::from_json(p)?,
            None => PlatformConfig::paper_default(),
        };
        let mut cfg = SimConfig::from_platform(platform);
        cfg.interval = j.f64_or("interval", cfg.interval);
        cfg.cpu_idle_timeout = j.f64_or("cpu_idle_timeout", cfg.cpu_idle_timeout);
        cfg.fpga_idle_timeout = j.f64_or("fpga_idle_timeout", cfg.fpga_idle_timeout);
        cfg.deadline_factor = j.f64_or("deadline_factor", cfg.deadline_factor);
        cfg.max_cpus = j.get("max_cpus").and_then(Json::as_u64).map(|v| v as u32);
        cfg.max_fpgas = j.get("max_fpgas").and_then(Json::as_u64).map(|v| v as u32);
        cfg.deadline_aware = j.bool_or("deadline_aware", false);
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

/// Request-size buckets from §5.1 / Table 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeBucket {
    /// 10ms – 100ms
    Short,
    /// 100ms – 1s
    Medium,
    /// 1s – 10s
    Long,
}

impl SizeBucket {
    pub fn bounds(&self) -> (f64, f64) {
        match self {
            SizeBucket::Short => (0.010, 0.100),
            SizeBucket::Medium => (0.100, 1.0),
            SizeBucket::Long => (1.0, 10.0),
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "short" => SizeBucket::Short,
            "medium" => SizeBucket::Medium,
            "long" => SizeBucket::Long,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SizeBucket::Short => "short",
            SizeBucket::Medium => "medium",
            SizeBucket::Long => "long",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table6() {
        let c = SimConfig::paper_default();
        assert_eq!(c.platform.cpu.spin_up, 0.005);
        assert_eq!(c.platform.fpga.spin_up, 10.0);
        assert_eq!(c.platform.cpu.busy_power, 150.0);
        assert_eq!(c.platform.fpga.busy_power, 50.0);
        assert_eq!(c.platform.cpu.idle_power, 30.0);
        assert_eq!(c.platform.fpga.idle_power, 20.0);
        assert_eq!(c.platform.fpga.speedup, 2.0);
        assert!((c.platform.cpu.cost_per_hour - 0.668).abs() < 1e-9);
        assert!((c.platform.fpga.cost_per_hour - 0.982).abs() < 1e-9);
        assert_eq!(c.interval, 10.0); // T_s = A_f
        assert_eq!(c.deadline_factor, 10.0);
    }

    #[test]
    fn spin_up_energy_matches_section_3_2() {
        // CPU 0.75 J, FPGA 500 J (busy power drawn during spin up).
        let c = SimConfig::paper_default();
        assert!((c.platform.cpu.spin_up_energy() - 0.75).abs() < 1e-9);
        assert!((c.platform.fpga.spin_up_energy() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn scheduler_names_round_trip() {
        for k in SchedulerKind::table8_roster()
            .into_iter()
            .chain(SchedulerKind::scenario_roster())
        {
            let name = k.name();
            assert_eq!(SchedulerKind::from_name(&name), Some(k.clone()), "{name}");
        }
        assert_eq!(SchedulerKind::from_name("bogus"), None);
    }

    #[test]
    fn scenario_roster_excluded_from_table8() {
        for k in [
            SchedulerKind::GreedySpot,
            SchedulerKind::OndemandFallback,
            SchedulerKind::SporkFallback,
        ] {
            assert!(!SchedulerKind::table8_roster().contains(&k), "{}", k.name());
            assert!(SchedulerKind::scenario_roster().contains(&k), "{}", k.name());
        }
    }

    #[test]
    fn json_round_trip() {
        let mut c = SimConfig::paper_default();
        c.max_fpgas = Some(128);
        c.deadline_aware = true;
        c.platform.fpga.spin_up = 60.0;
        let j = c.to_json();
        let c2 = SimConfig::from_json(&j).unwrap();
        assert_eq!(c2.max_fpgas, Some(128));
        assert!(c2.deadline_aware);
        assert_eq!(c2.platform.fpga.spin_up, 60.0);
        assert_eq!(c2.interval, c.interval);
    }

    #[test]
    fn size_buckets() {
        assert_eq!(SizeBucket::Short.bounds(), (0.010, 0.100));
        assert_eq!(SizeBucket::from_name("long"), Some(SizeBucket::Long));
        assert_eq!(SizeBucket::from_name("huge"), None);
    }

    #[test]
    fn dispatch_policy_names() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::IndexPacking,
            DispatchPolicy::EfficientFirst,
        ] {
            assert_eq!(DispatchPolicy::from_name(p.name()), Some(p));
        }
    }
}
