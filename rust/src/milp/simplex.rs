//! Dense primal simplex for linear programs in standard computational
//! form, built from scratch (no LP solver exists in the offline registry).
//!
//! Problem shape:  minimize cᵀx  s.t.  A x ⋛ b,  lo ≤ x ≤ up.
//! Internally converted to equality form with slack variables and solved
//! with a Big-M phase-free bounded-variable simplex. Sized for the small
//! cross-validation instances of `opt::dp` (tens of variables), not for
//! production-scale LPs — the scalable path is the DP.

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

#[derive(Clone, Debug)]
pub struct Constraint {
    /// Sparse row: (column, coefficient).
    pub terms: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// LP model builder.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    /// Objective coefficients (minimization).
    pub objective: Vec<f64>,
    /// Variable bounds (lo, hi). `hi` may be `f64::INFINITY`.
    pub bounds: Vec<(f64, f64)>,
    pub constraints: Vec<Constraint>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum LpError {
    Infeasible,
    Unbounded,
    IterationLimit,
}

impl fmt::Display for LpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LpError::Infeasible => write!(f, "infeasible"),
            LpError::Unbounded => write!(f, "unbounded"),
            LpError::IterationLimit => write!(f, "iteration limit"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
}

impl Lp {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a variable with cost `c` and bounds [lo, hi]; returns its index.
    pub fn var(&mut self, c: f64, lo: f64, hi: f64) -> usize {
        assert!(lo <= hi, "invalid bounds");
        self.objective.push(c);
        self.bounds.push((lo, hi));
        self.objective.len() - 1
    }

    pub fn constrain(&mut self, terms: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        self.constraints.push(Constraint { terms, cmp, rhs });
    }

    pub fn num_vars(&self) -> usize {
        self.objective.len()
    }

    /// Solve with the tableau Big-M simplex. Shifts variables so all lower
    /// bounds are 0; upper bounds become explicit ≤ rows (fine at this
    /// scale).
    pub fn solve(&self) -> Result<LpSolution, LpError> {
        let n = self.num_vars();
        // Shift x' = x - lo.
        let lo: Vec<f64> = self.bounds.iter().map(|b| b.0).collect();

        // Assemble rows: constraints (with shifted rhs) + finite upper
        // bounds as x' <= hi-lo.
        let mut rows: Vec<(Vec<f64>, Cmp, f64)> = Vec::new();
        for c in &self.constraints {
            let mut dense = vec![0.0; n];
            let mut shift = 0.0;
            for &(j, a) in &c.terms {
                dense[j] += a;
                shift += a * lo[j];
            }
            rows.push((dense, c.cmp, c.rhs - shift));
        }
        for (j, &(l, h)) in self.bounds.iter().enumerate() {
            if l > h {
                return Err(LpError::Infeasible);
            }
            if h.is_finite() {
                // Includes h == l (pins the shifted variable at 0).
                let mut dense = vec![0.0; n];
                dense[j] = 1.0;
                rows.push((dense, Cmp::Le, h - l));
            }
        }
        // Normalize to rhs >= 0.
        for (dense, cmp, rhs) in rows.iter_mut() {
            if *rhs < 0.0 {
                for a in dense.iter_mut() {
                    *a = -*a;
                }
                *rhs = -*rhs;
                *cmp = match *cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                };
            }
        }

        let m = rows.len();
        // Columns: n structural + slacks/surplus + artificials.
        let n_slack = rows
            .iter()
            .filter(|(_, cmp, _)| *cmp != Cmp::Eq)
            .count();
        let n_art = rows
            .iter()
            .filter(|(_, cmp, _)| *cmp != Cmp::Le)
            .count();
        let total = n + n_slack + n_art;
        let big_m = {
            let maxc = self
                .objective
                .iter()
                .fold(1.0f64, |acc, &c| acc.max(c.abs()));
            maxc * 1e7
        };

        // Tableau: m rows x (total + 1) [last col = rhs].
        let mut t = vec![vec![0.0; total + 1]; m];
        let mut cost = vec![0.0; total];
        cost[..n].copy_from_slice(&self.objective);
        let mut basis = vec![usize::MAX; m];
        let mut s_idx = n;
        let mut a_idx = n + n_slack;
        for (i, (dense, cmp, rhs)) in rows.iter().enumerate() {
            t[i][..n].copy_from_slice(dense);
            t[i][total] = *rhs;
            match cmp {
                Cmp::Le => {
                    t[i][s_idx] = 1.0;
                    basis[i] = s_idx;
                    s_idx += 1;
                }
                Cmp::Ge => {
                    t[i][s_idx] = -1.0;
                    s_idx += 1;
                    t[i][a_idx] = 1.0;
                    cost[a_idx] = big_m;
                    basis[i] = a_idx;
                    a_idx += 1;
                }
                Cmp::Eq => {
                    t[i][a_idx] = 1.0;
                    cost[a_idx] = big_m;
                    basis[i] = a_idx;
                    a_idx += 1;
                }
            }
        }

        // Reduced costs row.
        let mut z = vec![0.0; total + 1];
        for j in 0..=total {
            let mut s = 0.0;
            for i in 0..m {
                s += cost[basis[i]] * t[i][j];
            }
            z[j] = s - if j < total { cost[j] } else { 0.0 };
        }

        let max_iter = 50_000.max(200 * total);
        for _ in 0..max_iter {
            // Entering: most positive z_j (Dantzig) with tolerance.
            let mut enter = None;
            let mut best = 1e-9;
            for (j, &zj) in z[..total].iter().enumerate() {
                if zj > best {
                    best = zj;
                    enter = Some(j);
                }
            }
            let Some(e) = enter else {
                // Optimal. Check artificials.
                for i in 0..m {
                    if basis[i] >= n + n_slack && t[i][total] > 1e-6 {
                        return Err(LpError::Infeasible);
                    }
                }
                let mut x = lo.clone();
                for i in 0..m {
                    if basis[i] < n {
                        x[basis[i]] += t[i][total];
                    }
                }
                let objective = self
                    .objective
                    .iter()
                    .zip(&x)
                    .map(|(c, v)| c * v)
                    .sum();
                return Ok(LpSolution { x, objective });
            };
            // Leaving: min ratio.
            let mut leave = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..m {
                if t[i][e] > 1e-9 {
                    let ratio = t[i][total] / t[i][e];
                    if ratio < best_ratio - 1e-12 {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(l) = leave else {
                return Err(LpError::Unbounded);
            };
            // Pivot.
            let piv = t[l][e];
            for v in t[l].iter_mut() {
                *v /= piv;
            }
            for i in 0..m {
                if i != l && t[i][e].abs() > 1e-12 {
                    let f = t[i][e];
                    for j in 0..=total {
                        t[i][j] -= f * t[l][j];
                    }
                }
            }
            let f = z[e];
            if f.abs() > 1e-12 {
                for j in 0..=total {
                    z[j] -= f * t[l][j];
                }
            }
            basis[l] = e;
        }
        Err(LpError::IterationLimit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_min() {
        // min x+y st x+y >= 2, x <= 1.5 → x=1.5? any split; obj = 2.
        let mut lp = Lp::new();
        let x = lp.var(1.0, 0.0, 1.5);
        let y = lp.var(1.0, 0.0, f64::INFINITY);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 2.0);
        let s = lp.solve().unwrap();
        assert!((s.objective - 2.0).abs() < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn prefers_cheaper_variable() {
        // min 3x + y st x + y >= 4, y <= 3 → y=3, x=1 → obj 6.
        let mut lp = Lp::new();
        let x = lp.var(3.0, 0.0, f64::INFINITY);
        let y = lp.var(1.0, 0.0, 3.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        let s = lp.solve().unwrap();
        assert!((s.x[x] - 1.0).abs() < 1e-6);
        assert!((s.x[y] - 3.0).abs() < 1e-6);
        assert!((s.objective - 6.0).abs() < 1e-6);
    }

    #[test]
    fn equality_and_le() {
        // min 2a + b st a + b = 5, a <= 2 → a=2,b=3 obj 7.
        let mut lp = Lp::new();
        let a = lp.var(2.0, 0.0, f64::INFINITY);
        let b = lp.var(1.0, 0.0, f64::INFINITY);
        lp.constrain(vec![(a, 1.0), (b, 1.0)], Cmp::Eq, 5.0);
        lp.constrain(vec![(a, 1.0)], Cmp::Le, 2.0);
        let s = lp.solve().unwrap();
        // a is costlier → a=0, b=5, obj 5.
        assert!((s.objective - 5.0).abs() < 1e-6, "obj {}", s.objective);
        assert!((s.x[a]).abs() < 1e-6);
        assert!((s.x[b] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new();
        let x = lp.var(1.0, 0.0, 1.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Ge, 5.0);
        assert_eq!(lp.solve().unwrap_err(), LpError::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new();
        let x = lp.var(-1.0, 0.0, f64::INFINITY);
        lp.constrain(vec![(x, 1.0)], Cmp::Ge, 0.0);
        assert_eq!(lp.solve().unwrap_err(), LpError::Unbounded);
    }

    #[test]
    fn lower_bounds_shifted() {
        // min x st x >= 2 (bound), x + y >= 5, y in [1, 2] → x=3,y=2 obj 3.
        let mut lp = Lp::new();
        let x = lp.var(1.0, 2.0, f64::INFINITY);
        let y = lp.var(0.0, 1.0, 2.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 5.0);
        let s = lp.solve().unwrap();
        assert!((s.x[x] - 3.0).abs() < 1e-6, "x={}", s.x[x]);
        assert!((s.x[y] - 2.0).abs() < 1e-6, "y={}", s.x[y]);
    }

    #[test]
    fn negative_rhs_handled() {
        // min x st -x <= -3  (i.e. x >= 3)
        let mut lp = Lp::new();
        let x = lp.var(1.0, 0.0, f64::INFINITY);
        lp.constrain(vec![(x, -1.0)], Cmp::Le, -3.0);
        let s = lp.solve().unwrap();
        assert!((s.x[x] - 3.0).abs() < 1e-6);
    }
}
