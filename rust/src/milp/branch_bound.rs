//! Branch & bound MILP solver over the [`super::simplex`] LP relaxation.
//!
//! Depth-first, most-fractional branching, best-incumbent pruning. Sized
//! for the cross-validation instances (a handful of integer variables over
//! a few intervals), matching its role: certifying the scalable DP
//! (`opt::dp`) against the paper's Table 3 formulation on small cases.

use super::simplex::{Cmp, Lp, LpError, LpSolution};

#[derive(Clone, Debug)]
pub struct Milp {
    pub lp: Lp,
    /// Indices of variables constrained to integers.
    pub integers: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum MilpError {
    Infeasible,
    Unbounded,
    NodeLimit,
}

impl Milp {
    pub fn new() -> Self {
        Self {
            lp: Lp::new(),
            integers: Vec::new(),
        }
    }

    /// Add an integer variable.
    pub fn int_var(&mut self, c: f64, lo: f64, hi: f64) -> usize {
        let j = self.lp.var(c, lo, hi);
        self.integers.push(j);
        j
    }

    /// Add a continuous variable.
    pub fn var(&mut self, c: f64, lo: f64, hi: f64) -> usize {
        self.lp.var(c, lo, hi)
    }

    pub fn constrain(&mut self, terms: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        self.lp.constrain(terms, cmp, rhs);
    }

    pub fn solve(&self, node_limit: usize) -> Result<LpSolution, MilpError> {
        const TOL: f64 = 1e-6;
        let mut best: Option<LpSolution> = None;
        // Stack of bound overrides: Vec<(var, lo, hi)>.
        let mut stack: Vec<Vec<(usize, f64, f64)>> = vec![Vec::new()];
        let mut nodes = 0usize;
        let mut any_feasible_relaxation = false;

        while let Some(overrides) = stack.pop() {
            nodes += 1;
            if nodes > node_limit {
                return best.ok_or(MilpError::NodeLimit);
            }
            let mut lp = self.lp.clone();
            let mut empty_box = false;
            for &(j, lo, hi) in &overrides {
                let b = &mut lp.bounds[j];
                b.0 = b.0.max(lo);
                b.1 = b.1.min(hi);
                if b.0 > b.1 {
                    empty_box = true;
                    break;
                }
            }
            if empty_box {
                continue; // skip the node entirely
            }
            let sol = match lp.solve() {
                Ok(s) => s,
                Err(LpError::Infeasible) => continue,
                Err(LpError::Unbounded) => return Err(MilpError::Unbounded),
                Err(LpError::IterationLimit) => continue, // treat as pruned
            };
            any_feasible_relaxation = true;
            // Prune on bound.
            if let Some(b) = &best {
                if sol.objective >= b.objective - 1e-9 {
                    continue;
                }
            }
            // Most-fractional integer variable.
            let frac = self
                .integers
                .iter()
                .map(|&j| {
                    let f = sol.x[j] - sol.x[j].floor();
                    (j, f.min(1.0 - f))
                })
                .filter(|&(_, d)| d > TOL)
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            match frac {
                None => {
                    // Integral: snap and accept as incumbent.
                    let mut snapped = sol.clone();
                    for &j in &self.integers {
                        snapped.x[j] = snapped.x[j].round();
                    }
                    best = Some(snapped);
                }
                Some((j, _)) => {
                    let v = sol.x[j];
                    let mut down = overrides.clone();
                    down.push((j, f64::NEG_INFINITY, v.floor()));
                    let mut up = overrides;
                    up.push((j, v.ceil(), f64::INFINITY));
                    // Explore the closer branch first (DFS).
                    if v - v.floor() < 0.5 {
                        stack.push(up);
                        stack.push(down);
                    } else {
                        stack.push(down);
                        stack.push(up);
                    }
                }
            }
        }
        match best {
            Some(b) => Ok(b),
            None if any_feasible_relaxation => Err(MilpError::Infeasible),
            None => Err(MilpError::Infeasible),
        }
    }
}

impl Default for Milp {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_like() {
        // max 5a + 4b st 6a + 5b <= 10, a,b in {0,1,2}
        // → min -5a -4b. Optimal integer: a=0,b=2 → -8.
        let mut m = Milp::new();
        let a = m.int_var(-5.0, 0.0, 2.0);
        let b = m.int_var(-4.0, 0.0, 2.0);
        m.constrain(vec![(a, 6.0), (b, 5.0)], Cmp::Le, 10.0);
        let s = m.solve(1000).unwrap();
        assert!((s.objective + 8.0).abs() < 1e-6, "obj {}", s.objective);
        assert_eq!(s.x[a] as i64, 0);
        assert_eq!(s.x[b] as i64, 2);
    }

    #[test]
    fn integrality_matters() {
        // min x st 2x >= 3, x integer → x=2 (LP gives 1.5).
        let mut m = Milp::new();
        let x = m.int_var(1.0, 0.0, 10.0);
        m.constrain(vec![(x, 2.0)], Cmp::Ge, 3.0);
        let s = m.solve(100).unwrap();
        assert_eq!(s.x[x] as i64, 2);
    }

    #[test]
    fn mixed_integer_continuous() {
        // min 10n + y st n*4 + y >= 9, y <= 3, n int → n=2,y=1 → 21.
        let mut m = Milp::new();
        let n = m.int_var(10.0, 0.0, 5.0);
        let y = m.var(1.0, 0.0, 3.0);
        m.constrain(vec![(n, 4.0), (y, 1.0)], Cmp::Ge, 9.0);
        let s = m.solve(1000).unwrap();
        assert!((s.objective - 21.0).abs() < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn infeasible_integer_box() {
        // 0.4 <= x <= 0.6, x integer → infeasible.
        let mut m = Milp::new();
        let x = m.int_var(1.0, 0.4, 0.6);
        m.constrain(vec![(x, 1.0)], Cmp::Ge, 0.0);
        assert!(m.solve(100).is_err());
    }

    #[test]
    fn matches_exhaustive_on_random_small() {
        // Randomized 2-int-var problems vs brute force.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..30 {
            let c1 = rng.range_f64(0.5, 5.0);
            let c2 = rng.range_f64(0.5, 5.0);
            let a1 = rng.range_f64(1.0, 4.0);
            let a2 = rng.range_f64(1.0, 4.0);
            let rhs = rng.range_f64(2.0, 12.0);
            let mut m = Milp::new();
            let x = m.int_var(c1, 0.0, 6.0);
            let y = m.int_var(c2, 0.0, 6.0);
            m.constrain(vec![(x, a1), (y, a2)], Cmp::Ge, rhs);
            let s = m.solve(10_000).unwrap();
            // Brute force.
            let mut best = f64::INFINITY;
            for xi in 0..=6 {
                for yi in 0..=6 {
                    if a1 * xi as f64 + a2 * yi as f64 >= rhs - 1e-9 {
                        best = best.min(c1 * xi as f64 + c2 * yi as f64);
                    }
                }
            }
            assert!(
                (s.objective - best).abs() < 1e-5,
                "milp {} vs brute {best} (c=({c1},{c2}) a=({a1},{a2}) rhs={rhs})",
                s.objective
            );
        }
    }
}
