//! Mixed-integer linear programming substrate, built from scratch (the
//! offline registry has no solver crates): a dense bounded-variable
//! simplex ([`simplex`]) and a branch & bound wrapper ([`branch_bound`]).
//! Used to certify the scalable fluid-model DP against the paper's
//! Table 3 formulation on small instances.

pub mod branch_bound;
pub mod simplex;

pub use branch_bound::{Milp, MilpError};
pub use simplex::{Cmp, Lp, LpError, LpSolution};
