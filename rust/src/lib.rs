//! # Spork — hybrid FPGA-CPU scheduling for interactive datacenter apps
//!
//! Reproduction of *"Hybrid Computing for Interactive Datacenter
//! Applications"* (CS.DC 2023): a hybrid scheduler that serves stable-state
//! load on energy-efficient FPGAs and absorbs bursts with fast-spinning
//! CPUs, trading off energy against cost.
//!
//! Architecture (see `DESIGN.md`):
//! * **L3 (this crate)** — schedulers, discrete-event simulator, offline
//!   pareto-optimal solvers, trace generators, serving runtime, experiment
//!   harness.
//! * **L2/L1 (python/, build-time only)** — the served application (MLP
//!   inference) as JAX + Pallas, AOT-lowered to HLO text under
//!   `artifacts/`, executed here via PJRT (`runtime`).

pub mod cli;
pub mod config;
pub mod exp;
pub mod milp;
pub mod opt;
pub mod policy;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;
