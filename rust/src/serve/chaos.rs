//! Wall-clock chaos injection for the serve path.
//!
//! A [`ChaosSpec`] attaches a deterministic scenario pack (PR 7's
//! [`FaultPlan`] machinery, unchanged: same `(config, seed_base, seed,
//! duration)` ⇒ same plan, same digest) to a serving run. The replay
//! contract is the pacing loop itself: the plan's strikes and price ticks
//! enter the shared driver's event heap exactly as in the simulator, and
//! because the router paces *every* model occurrence against the wall
//! clock, each fault fires at its scaled wall time — `t_wall = t_sim /
//! time_scale` from the run epoch. Under [`super::Compute::Real`] the
//! model's `Killed` effect parks the bound physical worker thread (the
//! existing kill-mirroring path), so a planned preemption really does
//! yank a running thread out from under its queue at a paced wall
//! instant.
//!
//! On top of the model-side plan, a spec can arm *wall-side* exec
//! injection for real compute: each applied hardware-failure strike also
//! sends one surviving bound slot a [`super::worker::WorkerMsg::Inject`],
//! stalling its next batch by `stall_wall` seconds (a slowdown the exec-
//! overrun accounting observes) and optionally dropping the batch's
//! completion records (the shutdown drain's `recv_timeout` and the
//! `completions_dropped` counter make the loss visible instead of
//! hanging). Model accounting is authoritative either way — wall
//! injection perturbs measurements, never the decision loop, so the
//! sim-vs-serve parity contract survives chaos.
//!
//! Determinism: the model-side replay is a pure function of the spec
//! (plan determinism) and the policy (shared driver). A fault-free pack
//! builds an empty plan, and an attached-but-empty plan is bit-identical
//! to no attachment at all (pinned by `rust/tests/serve_chaos.rs`).

use crate::scenario::{FaultPlan, ScenarioConfig};

/// A chaos pack bound to seeds: everything needed to rebuild the exact
/// fault plan of a serving run (and for `tools/scenario_oracle.py` to
/// recompute its digest from scratch).
#[derive(Clone, Debug)]
pub struct ChaosSpec {
    /// The scenario pack replayed against the serve driver. Its
    /// `retry_budget` is the same field the sim's kill path enforces, and
    /// serve recovery derives its retry window from it — one budget.
    pub scenario: ScenarioConfig,
    pub seed_base: u64,
    pub seed: u64,
    /// Wall-side stall injected into one surviving worker's next batch per
    /// applied failure strike, wall seconds. 0 disables (model-side chaos
    /// only). Only meaningful under real compute.
    pub stall_wall: f64,
    /// Whether wall-side injection also drops the stalled batch's
    /// completion records (simulating a worker that wedges without
    /// reporting). Only meaningful under real compute with
    /// `stall_wall > 0`.
    pub drop_completions: bool,
}

impl ChaosSpec {
    /// A pack by name (`fault-free`/`none`, `mild`, `severe`) with
    /// model-side injection only.
    pub fn from_name(pack: &str, seed_base: u64, seed: u64) -> Option<Self> {
        ScenarioConfig::from_name(pack).map(|scenario| ChaosSpec {
            scenario,
            seed_base,
            seed,
            stall_wall: 0.0,
            drop_completions: false,
        })
    }

    /// Validate the spec before a run: the scenario pack must validate
    /// (which also bounds the shared retry budget) and the wall-side
    /// knobs must be finite and non-negative.
    pub fn validate(&self) -> Result<(), String> {
        self.scenario.validate()?;
        if !(self.stall_wall.is_finite() && self.stall_wall >= 0.0) {
            return Err(format!(
                "chaos: stall_wall must be finite and >= 0 (got {})",
                self.stall_wall
            ));
        }
        Ok(())
    }

    /// The exact plan a serve run over `duration` sim-seconds replays —
    /// pure, so reports can carry its digest and counts for independent
    /// re-derivation.
    pub fn plan(&self, duration: f64) -> FaultPlan {
        FaultPlan::build(&self.scenario, self.seed_base, self.seed, duration)
    }
}

/// Summary of the plan a run replayed, carried on [`super::ServeReport`]
/// so the Python oracle can recompute digest and counts from scratch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosPlanInfo {
    /// Pack name ("" when no chaos was attached).
    pub pack: String,
    pub seed_base: u64,
    pub seed: u64,
    /// Order-sensitive plan digest. For sharded runs: per-app digests
    /// folded in app-index order with the same `rotl(7)`/golden-ratio mix
    /// the plan digest itself uses (see [`combine_digest`]).
    pub digest: u64,
    pub price_ticks: u64,
    pub preemptions: u64,
    pub failures: u64,
}

/// Fold one app's plan digest into a combined sharded-run digest. Same
/// mixing step as `FaultPlan::digest`, applied over per-app digests in
/// app-index order — deterministic for any shard count, and trivially
/// re-derivable by the oracle.
pub fn combine_digest(h: u64, app_digest: u64) -> u64 {
    (h.rotate_left(7) ^ app_digest).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_plans_are_deterministic_and_named() {
        let a = ChaosSpec::from_name("severe", 7, 3).unwrap();
        let b = ChaosSpec::from_name("severe", 7, 3).unwrap();
        let pa = a.plan(50.0);
        let pb = b.plan(50.0);
        assert_eq!(pa, pb);
        assert_eq!(pa.digest(), pb.digest());
        assert!(!pa.is_empty());
        assert!(ChaosSpec::from_name("bogus", 0, 0).is_none());
    }

    #[test]
    fn fault_free_spec_builds_an_empty_plan() {
        let s = ChaosSpec::from_name("fault-free", 1, 0).unwrap();
        let p = s.plan(3600.0);
        assert!(p.is_empty());
        assert_eq!(p.digest(), 0);
    }

    #[test]
    fn validate_gates_wall_knobs() {
        let mut s = ChaosSpec::from_name("mild", 1, 0).unwrap();
        assert!(s.validate().is_ok());
        s.stall_wall = f64::NAN;
        assert!(s.validate().is_err());
        s.stall_wall = -1.0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn combined_digest_is_order_sensitive() {
        let d1 = combine_digest(combine_digest(0, 11), 22);
        let d2 = combine_digest(combine_digest(0, 22), 11);
        assert_ne!(d1, d2);
    }
}
