//! Sharded router: partition the app set across N independent router
//! shards, each owning a disjoint slice of arrival sources, policies, and
//! worker-pool budgets (DESIGN.md §13).
//!
//! Apps are the sharding unit because they are the system's natural
//! isolation boundary — each app has its own pool (FPGAs are
//! application-specific bitstreams), its own policy instance, and its own
//! arrival stream, so shards share *nothing* and need no locks. App `i`
//! goes to shard `i % shards` ([`partition_round_robin`]); per-app results
//! are merged back in app-index order. Both rules depend only on the app
//! index, never on timing, so the merged [`ServeReport`] is **bit
//! identical for any shard count** (pinned by
//! `rust/tests/serve_line_rate.rs`) — sharding buys wall-clock
//! parallelism, not different answers.
//!
//! Determinism of the inputs is the caller's half of the contract: build
//! each app's source and policy from the app *index* (e.g. via
//! `Rng::for_stream(seed, app_index)` or the production generator's
//! per-app forks), not from anything shard- or thread-dependent.

use super::chaos::{combine_digest, ChaosPlanInfo};
use super::{Backpressure, Compute, Recovery, RecoveryConfig, ServeConfig, ServeReport};
use crate::policy::{Effect, Policy};
use crate::scenario::FaultCounts;
use crate::sim::{Driver, Metrics};
use crate::trace::{partition_round_robin, ArrivalSource};
use crate::util::stats::LogHistogram;
use std::time::{Duration, Instant};

/// One app's serving inputs: its arrival stream, its policy instance, and
/// its warm-pool budget (per-app pools, like the simulator).
pub struct AppServe {
    pub source: Box<dyn ArrivalSource>,
    pub policy: Box<dyn Policy>,
    pub pool_cpus: usize,
    pub pool_fpgas: usize,
}

/// Deferred app construction: factories cross the shard-thread boundary
/// (sources and policies are not `Send`), so each shard builds its own
/// apps. A factory must be a pure function of the app's identity for the
/// shard-count determinism contract to hold.
pub type AppFactory = Box<dyn FnOnce() -> AppServe + Send>;

/// Per-app result a shard hands back for the index-ordered merge.
struct AppOutcome {
    idx: usize,
    scheduler: String,
    metrics: Metrics,
    latency: LogHistogram,
    sim_end: f64,
    max_lag_wall: f64,
    /// This app's chaos plan `(digest, counts)` — a pure function of the
    /// app index (seed `chaos.seed + idx`), so the merged digest is shard-
    /// count independent. `None` without chaos.
    plan: Option<(u64, FaultCounts)>,
}

/// Run `apps` across `shards` router shards and merge their reports.
///
/// Supports [`Compute::Stub`] (as fast as possible) and [`Compute::Paced`]
/// (each shard paces its own apps against one shared wall-clock epoch);
/// [`Compute::Real`] is single-router only — the physical worker pool's
/// slot binding lives in [`super::run_serve_source`].
pub fn run_serve_sharded(
    cfg: &ServeConfig,
    apps: Vec<AppFactory>,
    shards: usize,
    compute: Compute,
) -> anyhow::Result<ServeReport> {
    if compute == Compute::Real {
        return Err(anyhow::anyhow!(
            "sharded serving supports stubbed/paced compute only \
             (the physical worker pool binds to a single router)"
        ));
    }
    if let Some(c) = &cfg.chaos {
        c.validate().map_err(|e| anyhow::anyhow!(e))?;
    }
    let n_apps = apps.len();
    let parts = partition_round_robin(apps.into_iter().enumerate().collect(), shards);
    let epoch = Instant::now();

    let mut outcomes: Vec<AppOutcome> = Vec::with_capacity(n_apps);
    std::thread::scope(|s| {
        let handles: Vec<_> = parts
            .into_iter()
            .filter(|p| !p.is_empty())
            .map(|part| s.spawn(move || run_shard(cfg, part, compute, epoch)))
            .collect();
        for h in handles {
            outcomes.extend(h.join().expect("router shard panicked"));
        }
    });
    // Merge in app-index order: metric and histogram sums are f64, so a
    // fixed fold order is what makes the report shard-count independent.
    outcomes.sort_by_key(|o| o.idx);

    let mut metrics = Metrics::default();
    let mut latency = LogHistogram::latency_ms();
    let mut report = ServeReport::default();
    for o in &outcomes {
        metrics.merge(&o.metrics);
        latency.merge(&o.latency);
        report.sim_seconds = report.sim_seconds.max(o.sim_end);
        report.max_lag_wall = report.max_lag_wall.max(o.max_lag_wall);
    }
    report.scheduler = outcomes
        .first()
        .map(|o| o.scheduler.clone())
        .unwrap_or_default();
    report.requests = metrics.requests;
    report.on_cpu = metrics.on_cpu;
    report.on_fpga = metrics.on_fpga;
    report.misses = metrics.deadline_misses;
    report.shed = metrics.shed;
    report.completions = metrics.completions;
    report.abandoned = metrics.abandoned;
    report.retries = metrics.redispatches;
    report.hedges = metrics.hedges;
    report.hedge_wins = metrics.hedge_wins;
    report.quarantines = metrics.quarantines;
    report.recovered_deadline_hits = metrics.recovered_deadline_hits;
    report.preemptions = metrics.preemptions;
    report.worker_failures = metrics.worker_failures;
    report.fpga_spinups = metrics.fpga_spinups;
    report.cpu_spinups = metrics.cpu_spinups;
    report.energy_j = metrics.total_energy();
    report.cost_usd = metrics.total_cost();
    report.latency_ms = latency;
    report.wall_seconds = epoch.elapsed().as_secs_f64();
    if let Some(c) = &cfg.chaos {
        // Fold per-app digests in app-index order with the plan digest's
        // own mixing step — deterministic for any shard count, and
        // recomputable from scratch by `tools/scenario_oracle.py`.
        let mut digest = 0u64;
        let mut counts = FaultCounts::default();
        for o in &outcomes {
            if let Some((d, c)) = o.plan {
                digest = combine_digest(digest, d);
                counts.price_ticks += c.price_ticks;
                counts.preemptions += c.preemptions;
                counts.failures += c.failures;
            }
        }
        report.chaos = ChaosPlanInfo {
            pack: c.scenario.name.clone(),
            seed_base: c.seed_base,
            seed: c.seed,
            digest,
            price_ticks: counts.price_ticks,
            preemptions: counts.preemptions,
            failures: counts.failures,
        };
    }
    Ok(report)
}

fn record(lat: &mut LogHistogram, e: &Effect) {
    // Latency per *completed* request (exactly one `Completed` per
    // request, hedged or not) — on the fault-free path the same
    // (arrival, finish) multiset the dispatch stream carries, so
    // chaos-off merged reports stay bit-identical.
    if let Effect::Completed { arrival, finish, .. } = *e {
        lat.add((finish - arrival) * 1000.0);
    }
}

/// Drive one shard's apps to completion. Each app gets its own driver,
/// pool, and admission wrapper; under pacing the shard sleeps to the
/// absolute wall deadline of its earliest pending occurrence, then drains
/// *every* app up to the elapsed-time horizon in one batched-admission
/// burst (same per-app step order as unpaced stepping — apps share no
/// state, so cross-app drain order is immaterial).
fn run_shard(
    cfg: &ServeConfig,
    part: Vec<(usize, AppFactory)>,
    compute: Compute,
    epoch: Instant,
) -> Vec<AppOutcome> {
    let paced = compute != Compute::Stub;
    let scale = cfg.time_scale;
    let platform = cfg.platform.clone();
    let cap = cfg.queue_cap as u64;

    let mut idxs = Vec::with_capacity(part.len());
    let mut policies = Vec::with_capacity(part.len());
    let mut sources = Vec::with_capacity(part.len());
    let mut pools = Vec::with_capacity(part.len());
    for (idx, factory) in part {
        let app = factory();
        idxs.push(idx);
        policies.push(app.policy);
        sources.push(app.source);
        pools.push((app.pool_cpus, app.pool_fpgas));
    }
    // Same decorator chain as `run_serve_source`: shedding stays outermost
    // so an at-cap arrival is never seen by the recovery layer; with chaos
    // off the disabled `Recovery` is a verbatim forwarder (bit parity).
    let rcfg = cfg
        .chaos
        .as_ref()
        .map(|c| RecoveryConfig::for_scenario(&c.scenario))
        .unwrap_or_else(RecoveryConfig::disabled);
    let mut recoveries: Vec<Recovery> = policies
        .iter_mut()
        .map(|p| Recovery::new(p.as_mut(), rcfg.clone()))
        .collect();
    let mut wrapped: Vec<Backpressure> = recoveries
        .iter_mut()
        .map(|r| Backpressure::new(r as &mut dyn Policy, cap))
        .collect();
    let mut drivers: Vec<Driver> = wrapped
        .iter_mut()
        .zip(sources)
        .zip(&pools)
        .map(|((p, src), &(pc, pf))| {
            Driver::from_source(src, cfg.sim_config(pc, pf), p as &mut dyn Policy)
        })
        .collect();
    // Per-app fault plan, seeded by the *app index* (`chaos.seed + idx`)
    // so the plan an app replays never depends on which shard runs it.
    let plans: Vec<Option<(u64, FaultCounts)>> = if let Some(c) = &cfg.chaos {
        drivers
            .iter_mut()
            .zip(&idxs)
            .map(|(d, &idx)| {
                let plan =
                    d.attach_scenario(&c.scenario, c.seed_base, c.seed.wrapping_add(idx as u64));
                Some((plan.digest(), plan.counts()))
            })
            .collect()
    } else {
        vec![None; drivers.len()]
    };
    let mut lats: Vec<LogHistogram> = (0..drivers.len())
        .map(|_| LogHistogram::latency_ms())
        .collect();
    let mut max_lag_wall = 0.0f64;

    for i in 0..drivers.len() {
        let lat = &mut lats[i];
        drivers[i].start(&mut |e: &Effect| record(lat, e));
    }
    if !paced {
        // Stubbed compute: no clock to share, and the apps are fully
        // independent — run each to completion in turn.
        for i in 0..drivers.len() {
            let lat = &mut lats[i];
            let mut sink = |e: &Effect| record(lat, e);
            while drivers[i].step(&mut sink) {}
        }
    } else {
        loop {
            let mut next = f64::INFINITY;
            for d in &drivers {
                if let Some(t) = d.next_time() {
                    next = next.min(t);
                }
            }
            if !next.is_finite() {
                break;
            }
            // Drift-free pacing, as in `run_serve_source`: one absolute
            // deadline sleep per quantum for the whole shard.
            let target = epoch + Duration::from_secs_f64(next / scale);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            let elapsed = epoch.elapsed().as_secs_f64();
            max_lag_wall = max_lag_wall.max(elapsed - next / scale);
            let horizon = (elapsed * scale).max(next);
            for i in 0..drivers.len() {
                let lat = &mut lats[i];
                let mut sink = |e: &Effect| record(lat, e);
                drivers[i].step_until(horizon, &mut sink);
            }
        }
    }

    drivers
        .into_iter()
        .zip(lats)
        .zip(idxs)
        .zip(plans)
        .map(|(((d, latency), idx), plan)| {
            let sim_end = d.now();
            let result = d.finish(&platform);
            AppOutcome {
                idx,
                scheduler: result.scheduler.clone(),
                metrics: result.metrics,
                latency,
                sim_end,
                max_lag_wall,
                plan,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerKind;
    use crate::util::rng::Rng;

    fn app_factory(i: usize) -> AppFactory {
        Box::new(move || {
            // Pure function of the app index: the determinism contract.
            let mut rng = Rng::for_stream(42, i as u64);
            let trace = crate::trace::synthetic_app(
                &format!("app{i}"),
                &mut rng,
                0.6,
                120.0,
                20.0 + 5.0 * i as f64,
                0.010,
            );
            let cfg = super::super::ServeConfig::defaults("unused", 1e9);
            let sim_cfg = cfg.sim_config(8, 4);
            let policy = crate::sched::build(&SchedulerKind::spork_e(), &sim_cfg, &trace);
            AppServe {
                source: Box::new(trace.into_source()),
                policy,
                pool_cpus: 8,
                pool_fpgas: 4,
            }
        })
    }

    #[test]
    fn shard_counts_agree_bit_for_bit_under_stub_compute() {
        let cfg = super::super::ServeConfig::defaults("unused", 1e9);
        let run = |shards: usize| {
            let apps = (0..5).map(app_factory).collect();
            run_serve_sharded(&cfg, apps, shards, Compute::Stub).unwrap()
        };
        let one = run(1);
        assert!(one.requests > 1000, "workload too small to mean anything");
        assert_eq!(one.shed, 0);
        for shards in [2, 4, 7] {
            let many = run(shards);
            assert_eq!(one.requests, many.requests);
            assert_eq!(one.on_cpu, many.on_cpu);
            assert_eq!(one.on_fpga, many.on_fpga);
            assert_eq!(one.misses, many.misses);
            assert_eq!(
                one.energy_j.to_bits(),
                many.energy_j.to_bits(),
                "energy must merge identically at {shards} shards"
            );
            assert_eq!(one.cost_usd.to_bits(), many.cost_usd.to_bits());
            assert_eq!(one.sim_seconds.to_bits(), many.sim_seconds.to_bits());
            assert_eq!(one.latency_ms.count(), many.latency_ms.count());
            assert_eq!(
                one.latency_ms.percentile(99.0).to_bits(),
                many.latency_ms.percentile(99.0).to_bits()
            );
        }
    }

    #[test]
    fn real_compute_is_rejected() {
        let cfg = super::super::ServeConfig::defaults("unused", 1e9);
        let err = run_serve_sharded(&cfg, vec![app_factory(0)], 1, Compute::Real);
        assert!(err.is_err());
    }
}
