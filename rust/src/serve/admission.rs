//! Bounded-admission wrapper: backpressure as a policy combinator.
//!
//! [`Backpressure`] wraps any [`Policy`] and refuses fresh arrivals while
//! the fleet's in-flight backlog is at or above a cap, turning unbounded
//! admission queues into an explicit, counted `Shed` (DESIGN.md §13). The
//! check is one O(1) counter read ([`PolicyView::inflight_requests`]
//! against the pool's maintained total), so arming a cap never
//! reintroduces a per-arrival fleet scan. With `cap == 0` the wrapper is
//! inert — it forwards every observation untouched, so a capless wrapped
//! run is bit-identical to an unwrapped one (pinned by
//! `rust/tests/serve_line_rate.rs`).

use crate::policy::{Action, Observation, Policy, PolicyView};

/// Admission-bounding decorator around an inner policy. Sheds a fresh
/// arrival (and retries alike — a re-offered request competes for the same
/// bounded queue) when `cap > 0` and the in-flight backlog has reached the
/// cap; everything else forwards verbatim, and the inner policy never sees
/// the arrivals the wrapper sheds.
pub struct Backpressure<'a> {
    inner: &'a mut dyn Policy,
    cap: u64,
}

impl<'a> Backpressure<'a> {
    /// `cap == 0` disables shedding entirely (unbounded admission).
    pub fn new(inner: &'a mut dyn Policy, cap: u64) -> Self {
        Backpressure { inner, cap }
    }
}

impl Policy for Backpressure<'_> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn interval(&self) -> f64 {
        self.inner.interval()
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        if let Observation::Arrival { req } = obs {
            // The `cap > 0` guard short-circuits before the view query so
            // a capless wrapper issues no counter reads at all.
            if self.cap > 0 && view.inflight_requests() >= self.cap {
                out.push(Action::Shed { req });
                return;
            }
        }
        self.inner.observe(obs, view, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkerKind;
    use crate::policy::{Request, Target, WorkerId, WorkerObs};

    /// Inner policy that dispatches every arrival to worker 0 and records
    /// how many observations it saw.
    struct CountingInner {
        seen: usize,
    }

    impl Policy for CountingInner {
        fn name(&self) -> String {
            "counting-inner".into()
        }

        fn interval(&self) -> f64 {
            60.0
        }

        fn observe(&mut self, obs: Observation, _view: &dyn PolicyView, out: &mut Vec<Action>) {
            self.seen += 1;
            if let Observation::Arrival { req } = obs {
                out.push(Action::Dispatch {
                    req,
                    to: Target::Worker(WorkerId(0)),
                });
            }
        }
    }

    /// Minimal view with a fixed in-flight backlog.
    struct FixedView {
        inflight: u64,
    }

    impl PolicyView for FixedView {
        fn now(&self) -> f64 {
            0.0
        }
        fn trace_live(&self) -> bool {
            true
        }
        fn service_time(&self, _kind: WorkerKind, size: f64) -> f64 {
            size
        }
        fn allocated(&self, _kind: WorkerKind) -> u32 {
            0
        }
        fn live_ids(&self, _kind: WorkerKind) -> Vec<WorkerId> {
            Vec::new()
        }
        fn worker(&self, _id: WorkerId) -> Option<WorkerObs> {
            None
        }
        fn inflight_requests(&self) -> u64 {
            self.inflight
        }
    }

    fn arrival(t: f64) -> Observation {
        Observation::Arrival {
            req: Request {
                arrival: t,
                size: 1.0,
                deadline: t + 10.0,
                attempt: 0,
            },
        }
    }

    #[test]
    fn sheds_at_cap_and_hides_the_arrival_from_the_inner_policy() {
        let mut inner = CountingInner { seen: 0 };
        let mut bp = Backpressure::new(&mut inner, 4);
        let mut out = Vec::new();

        bp.observe(arrival(1.0), &FixedView { inflight: 3 }, &mut out);
        assert!(matches!(out.as_slice(), [Action::Dispatch { .. }]));
        out.clear();

        bp.observe(arrival(2.0), &FixedView { inflight: 4 }, &mut out);
        assert!(
            matches!(out.as_slice(), [Action::Shed { req }] if req.arrival == 2.0),
            "at-cap arrival must shed, got {out:?}"
        );
        out.clear();

        bp.observe(arrival(3.0), &FixedView { inflight: 9 }, &mut out);
        assert!(matches!(out.as_slice(), [Action::Shed { .. }]));

        // The inner policy saw only the admitted arrival.
        assert_eq!(inner.seen, 1);
    }

    #[test]
    fn cap_zero_is_inert_even_under_backlog() {
        let mut inner = CountingInner { seen: 0 };
        let mut bp = Backpressure::new(&mut inner, 0);
        let mut out = Vec::new();
        bp.observe(arrival(1.0), &FixedView { inflight: u64::MAX }, &mut out);
        assert!(matches!(out.as_slice(), [Action::Dispatch { .. }]));
        assert_eq!(inner.seen, 1);
    }

    #[test]
    fn non_arrival_observations_always_forward() {
        let mut inner = CountingInner { seen: 0 };
        let mut bp = Backpressure::new(&mut inner, 1);
        let mut out = Vec::new();
        bp.observe(Observation::Start, &FixedView { inflight: 10 }, &mut out);
        bp.observe(
            Observation::Tick {
                index: 0,
                cpu_work: 0.0,
                fpga_work: 0.0,
            },
            &FixedView { inflight: 10 },
            &mut out,
        );
        assert!(out.is_empty());
        assert_eq!(inner.seen, 2);
    }

    #[test]
    fn name_and_interval_forward() {
        let mut inner = CountingInner { seen: 0 };
        let bp = Backpressure::new(&mut inner, 7);
        assert_eq!(bp.name(), "counting-inner");
        assert_eq!(bp.interval(), 60.0);
    }
}
