//! Recovery decorator: deadline-aware retries, hedged dispatch, and a
//! per-worker circuit breaker — resilience as a policy combinator.
//!
//! [`Recovery`] sits between [`super::admission::Backpressure`] (outer)
//! and the scheduling policy (inner):
//!
//! ```text
//! driver → Backpressure → Recovery → policy
//! ```
//!
//! Shedding stays outermost so an at-cap fresh arrival never reaches the
//! recovery layer (no bookkeeping to leak); deferred retries re-enter the
//! driver through the event heap as [`Observation::RetryDue`], a
//! non-arrival observation the admission layer forwards verbatim — a
//! request admitted once is not shed on retry.
//!
//! Three mechanisms, all expressed through the ordinary action vocabulary
//! so the sim driver and the serve driver execute them identically:
//!
//! * **Deadline-aware retry with capped exponential backoff.** A
//!   re-offered arrival (`attempt > 0`) waits
//!   `backoff = min(base · 2^(attempt-1), cap)` before redispatch
//!   ([`Action::Defer`]). A retry is *never* attempted when the remaining
//!   deadline cannot cover it: if `now + backoff + min_svc > deadline`
//!   (with `min_svc` the fastest kind's service time for the request),
//!   the request is abandoned immediately ([`Action::Abandon`]) — an
//!   honest miss now instead of wasted work later. The retry *count*
//!   budget is the scenario pack's `retry_budget`, enforced by the
//!   driver's kill path; this layer mirrors the same field
//!   ([`RecoveryConfig::for_scenario`]) so the two can never drift.
//!
//! * **Hedged dispatch.** Every fresh dispatch arms a timer at
//!   `max(p_H completion latency, 2·min_svc)` past dispatch (H =
//!   `hedge_percentile`, from this layer's own [`LogHistogram`] of
//!   observed completion latencies; hedging stays dormant until
//!   `hedge_min_samples` completions so cold starts don't hedge on
//!   noise). If the request is still in flight when the timer fires and
//!   an idle spare exists (efficient-first: FPGA then CPU — an idle
//!   worker cannot be the one running the primary), the layer issues
//!   [`Action::Hedge`]: the driver dispatches a duplicate, first
//!   completion wins, the loser is cancelled and its energy stays billed.
//!
//! * **Circuit breaker.** `breaker_k` *consecutive* deadline-missed
//!   completions on one worker open a breaker ([`Action::Quarantine`] —
//!   counted and audited by the driver): dispatches targeting it are
//!   rerouted to the best non-quarantined worker (fail-open when none
//!   exists — a degraded worker beats a dropped request). After
//!   `breaker_cooldown` the next dispatch is let through as a half-open
//!   probe; an on-time completion closes the breaker, a missed one
//!   re-opens it for a fresh cool-down (and counts as a new quarantine).
//!
//! With `enabled == false` the decorator forwards every observation
//! verbatim and post-processes nothing — a disabled wrapped run is
//! bit-identical to an unwrapped one, which is what keeps the chaos-off
//! serve path's effect stream byte-stable (pinned by
//! `rust/tests/serve_chaos.rs`).

use std::collections::HashMap;

use crate::config::WorkerKind;
use crate::policy::{Action, Observation, Policy, PolicyView, Request, Target, WorkerId};
use crate::scenario::ScenarioConfig;
use crate::util::stats::LogHistogram;

/// Knobs for [`Recovery`]. Times are model (trace) seconds — the serve
/// driver's pacing maps them onto the wall clock exactly like every other
/// model duration.
#[derive(Clone, Debug)]
pub struct RecoveryConfig {
    /// Master switch: `false` makes the decorator a verbatim forwarder.
    pub enabled: bool,
    /// Mirror of the scenario pack's retry budget (same semantics as the
    /// sim kill path: a request with `attempt > retry_budget` is never
    /// redispatched).
    pub retry_budget: u32,
    /// First-retry backoff, seconds.
    pub backoff_base: f64,
    /// Backoff ceiling, seconds (`base · 2^(attempt-1)` is clamped here).
    pub backoff_cap: f64,
    /// Completion-latency percentile that sets the hedge threshold.
    /// `<= 0` disables hedging.
    pub hedge_percentile: f64,
    /// Completions observed before hedging arms.
    pub hedge_min_samples: u64,
    /// Consecutive deadline-missed completions that open a worker's
    /// breaker. `0` disables the breaker.
    pub breaker_k: u32,
    /// Quarantine duration before a half-open probe is allowed, seconds.
    pub breaker_cooldown: f64,
}

impl RecoveryConfig {
    /// The inert configuration: forwards everything, touches nothing.
    pub fn disabled() -> Self {
        RecoveryConfig {
            enabled: false,
            retry_budget: 0,
            backoff_base: 0.0,
            backoff_cap: 0.0,
            hedge_percentile: 0.0,
            hedge_min_samples: u64::MAX,
            breaker_k: 0,
            breaker_cooldown: 0.0,
        }
    }

    /// Recovery armed for a scenario pack, sharing its retry budget (one
    /// budget, one source — see `ScenarioConfig::validate`).
    pub fn for_scenario(scen: &ScenarioConfig) -> Self {
        RecoveryConfig {
            enabled: true,
            retry_budget: scen.retry_budget,
            backoff_base: 0.010,
            backoff_cap: 0.160,
            hedge_percentile: 95.0,
            hedge_min_samples: 50,
            breaker_k: 3,
            breaker_cooldown: 30.0,
        }
    }

    /// Sanity-check the knobs (finite, non-negative, percentile in range).
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("backoff_base", self.backoff_base),
            ("backoff_cap", self.backoff_cap),
            ("breaker_cooldown", self.breaker_cooldown),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("recovery: {name} must be finite and >= 0 (got {v})"));
            }
        }
        if !self.hedge_percentile.is_finite() || self.hedge_percentile > 100.0 {
            return Err(format!(
                "recovery: hedge_percentile must be finite and <= 100 (got {})",
                self.hedge_percentile
            ));
        }
        Ok(())
    }
}

/// Identity of one dispatched copy: `(arrival, size, deadline, attempt)`
/// bit patterns. Requests are `Copy` values, so this is the same matching
/// rule the driver's hedge path uses (`Request: PartialEq`).
type ReqKey = (u64, u64, u64, u32);

fn key(req: &Request) -> ReqKey {
    (
        req.arrival.to_bits(),
        req.size.to_bits(),
        req.deadline.to_bits(),
        req.attempt,
    )
}

/// Circuit-breaker state for one quarantined worker.
#[derive(Clone, Copy, Debug)]
struct Breaker {
    /// Quarantine end: before this, dispatches are rerouted away.
    until: f64,
    /// A probe dispatch has been let through; the next completion on the
    /// worker settles the breaker (on-time ⇒ close, missed ⇒ re-open).
    half_open: bool,
}

/// The recovery decorator. See the module docs for the contract.
pub struct Recovery<'a> {
    inner: &'a mut dyn Policy,
    cfg: RecoveryConfig,
    /// Observed completion latencies (ms) — the hedge-threshold source.
    lat: LogHistogram,
    /// In-flight copies by identity. Saturating bookkeeping: entries for
    /// hedge duplicates and cross-layer losses simply decay to no-ops
    /// (each timer fires once, so a stale entry can at most skip a hedge).
    live: HashMap<ReqKey, u32>,
    /// Armed hedge timers: token → the fresh dispatch it watches.
    timers: HashMap<u64, Request>,
    next_token: u64,
    /// Consecutive deadline-missed completions per worker.
    streak: HashMap<WorkerId, u32>,
    quarantined: HashMap<WorkerId, Breaker>,
}

impl<'a> Recovery<'a> {
    pub fn new(inner: &'a mut dyn Policy, cfg: RecoveryConfig) -> Self {
        Recovery {
            inner,
            cfg,
            lat: LogHistogram::latency_ms(),
            live: HashMap::new(),
            timers: HashMap::new(),
            next_token: 0,
            streak: HashMap::new(),
            quarantined: HashMap::new(),
        }
    }

    /// Fastest possible service time for a `size` request across kinds.
    fn min_svc(view: &dyn PolicyView, size: f64) -> f64 {
        WorkerKind::ALL
            .iter()
            .map(|&k| view.service_time(k, size))
            .fold(f64::INFINITY, f64::min)
    }

    fn dec_live(&mut self, k: ReqKey) {
        if let Some(v) = self.live.get_mut(&k) {
            if *v <= 1 {
                self.live.remove(&k);
            } else {
                *v -= 1;
            }
        }
    }

    fn hedging_armed(&self) -> bool {
        self.cfg.hedge_percentile > 0.0 && self.lat.count() >= self.cfg.hedge_min_samples
    }

    /// Whether a dispatch to `id` must be rerouted. A cooled-down breaker
    /// flips to half-open and admits the dispatch as its probe.
    fn gate(&mut self, now: f64, id: WorkerId) -> bool {
        match self.quarantined.get_mut(&id) {
            None => false,
            Some(b) if b.half_open => false,
            Some(b) if now >= b.until => {
                b.half_open = true;
                false
            }
            Some(_) => true,
        }
    }

    /// Breaker entry that still blocks dispatch (no probe side effects).
    fn blocked(&self, now: f64, id: WorkerId) -> bool {
        self.quarantined
            .get(&id)
            .map_or(false, |b| !b.half_open && now < b.until)
    }

    /// Best non-quarantined landing spot, efficient-first: most-recently-
    /// idle then earliest-finishing, FPGA before CPU. `None` ⇒ fail open
    /// (keep the original target — degraded beats dropped).
    fn reroute(&self, view: &dyn PolicyView, now: f64) -> Option<Target> {
        for &kind in &WorkerKind::EFFICIENT_FIRST {
            if let Some((_, id)) = view.most_recently_idle(kind) {
                if !self.blocked(now, id) {
                    return Some(Target::Worker(id));
                }
            }
        }
        for &kind in &WorkerKind::EFFICIENT_FIRST {
            if let Some((_, id)) = view.earliest_ready(kind) {
                if !self.blocked(now, id) {
                    return Some(Target::Worker(id));
                }
            }
        }
        None
    }

    /// Post-process the inner policy's freshly appended actions
    /// (`out[start..]`): steer dispatches away from open breakers, track
    /// copy liveness, and arm hedge timers on fresh dispatches.
    fn admit_dispatches(&mut self, view: &dyn PolicyView, out: &mut Vec<Action>, start: usize) {
        let now = view.now();
        let mut armed: Vec<Action> = Vec::new();
        for a in out[start..].iter_mut() {
            let (req, to, redispatch) = match *a {
                Action::Dispatch { req, to } => (req, to, false),
                Action::Redispatch { req, to } => (req, to, true),
                _ => continue,
            };
            let to = match to {
                Target::Worker(id) if self.gate(now, id) => {
                    self.reroute(view, now).unwrap_or(Target::Worker(id))
                }
                t => t,
            };
            *a = if redispatch {
                Action::Redispatch { req, to }
            } else {
                Action::Dispatch { req, to }
            };
            *self.live.entry(key(&req)).or_insert(0) += 1;
            if req.attempt == 0 && self.hedging_armed() {
                let p_lat = self.lat.percentile(self.cfg.hedge_percentile) / 1000.0;
                let threshold = p_lat.max(2.0 * Self::min_svc(view, req.size));
                let token = self.next_token;
                self.next_token += 1;
                self.timers.insert(token, req);
                armed.push(Action::Timer {
                    at: now + threshold,
                    token,
                });
            }
        }
        out.extend(armed);
    }

    /// Forward `obs` to the inner policy and post-process what it emits.
    fn forward(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        let start = out.len();
        self.inner.observe(obs, view, out);
        self.admit_dispatches(view, out, start);
    }

    fn on_retry_arrival(&mut self, req: Request, view: &dyn PolicyView, out: &mut Vec<Action>) {
        // The copy this retry replaces (previous attempt) is dead.
        let mut prev = req;
        prev.attempt -= 1;
        self.dec_live(key(&prev));

        let now = view.now();
        let exp = req.attempt.saturating_sub(1).min(32);
        let backoff = (self.cfg.backoff_base * f64::powi(2.0, exp as i32)).min(self.cfg.backoff_cap);
        let min_svc = Self::min_svc(view, req.size);
        if req.attempt > self.cfg.retry_budget || now + backoff + min_svc > req.deadline {
            // Retrying cannot meet the deadline (or the shared budget is
            // spent): abandon honestly instead of burning a worker.
            out.push(Action::Abandon { req });
        } else if backoff > 0.0 {
            out.push(Action::Defer {
                req,
                until: now + backoff,
            });
        } else {
            self.forward(Observation::Arrival { req }, view, out);
        }
    }

    fn on_completion(
        &mut self,
        worker: WorkerId,
        req: Request,
        view: &dyn PolicyView,
        out: &mut Vec<Action>,
    ) {
        self.dec_live(key(&req));
        let now = view.now();
        self.lat.add((now - req.arrival) * 1000.0);
        if now > req.deadline {
            let s = self.streak.entry(worker).or_insert(0);
            *s = s.saturating_add(1);
            let s = *s;
            match self.quarantined.get_mut(&worker) {
                Some(b) if b.half_open => {
                    // Failed probe: re-open for a fresh cool-down. The
                    // driver counts this as a new quarantine.
                    b.half_open = false;
                    b.until = now + self.cfg.breaker_cooldown;
                    out.push(Action::Quarantine { worker });
                }
                Some(_) => {}
                None => {
                    if self.cfg.breaker_k > 0 && s >= self.cfg.breaker_k {
                        self.quarantined.insert(
                            worker,
                            Breaker {
                                until: now + self.cfg.breaker_cooldown,
                                half_open: false,
                            },
                        );
                        out.push(Action::Quarantine { worker });
                    }
                }
            }
        } else {
            self.streak.remove(&worker);
            if self
                .quarantined
                .get(&worker)
                .map_or(false, |b| b.half_open)
            {
                // Successful probe: close the breaker.
                self.quarantined.remove(&worker);
            }
        }
        self.forward(Observation::Completion { worker, req }, view, out);
    }

    fn on_timer(&mut self, token: u64, view: &dyn PolicyView, out: &mut Vec<Action>) {
        let Some(req) = self.timers.remove(&token) else {
            // Not one of ours — an inner policy's own timer.
            self.forward(Observation::Timer { token }, view, out);
            return;
        };
        if self.live.get(&key(&req)).copied().unwrap_or(0) == 0 {
            return; // completed (or killed and re-offered) before the check
        }
        let now = view.now();
        for &kind in &WorkerKind::EFFICIENT_FIRST {
            if let Some((_, id)) = view.most_recently_idle(kind) {
                // An idle worker cannot be the one running the primary,
                // and we never hedge onto a quarantined worker.
                if !self.blocked(now, id) && !self.quarantined.contains_key(&id) {
                    out.push(Action::Hedge {
                        req,
                        to: Target::Worker(id),
                    });
                    return;
                }
            }
        }
        // No idle spare: skip the hedge rather than pile onto a busy
        // worker — the straggler may still finish.
    }
}

impl Policy for Recovery<'_> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn interval(&self) -> f64 {
        self.inner.interval()
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        if !self.cfg.enabled {
            // Verbatim forwarding: the disabled decorator must be
            // bit-invisible (chaos-off parity).
            self.inner.observe(obs, view, out);
            return;
        }
        match obs {
            Observation::Arrival { req } if req.attempt > 0 => {
                self.on_retry_arrival(req, view, out)
            }
            Observation::RetryDue { req } => {
                // Backoff matured: offer the retry to the inner policy as
                // an ordinary arrival (it was admitted before its kill, so
                // it does not re-compete for the admission cap).
                self.forward(Observation::Arrival { req }, view, out)
            }
            Observation::Timer { token } => self.on_timer(token, view, out),
            Observation::Completion { worker, req } => self.on_completion(worker, req, view, out),
            Observation::Abandoned { req } => {
                self.dec_live(key(&req));
                self.forward(obs, view, out)
            }
            Observation::Preempted { worker, .. } => {
                // The worker is gone; its breaker state dies with it.
                self.streak.remove(&worker);
                self.quarantined.remove(&worker);
                self.forward(obs, view, out)
            }
            _ => self.forward(obs, view, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{WorkerObs, WorkerState};

    /// Inner policy that dispatches every arrival to a fixed worker and
    /// counts what it sees.
    struct PinInner {
        to: WorkerId,
        seen: usize,
    }

    impl Policy for PinInner {
        fn name(&self) -> String {
            "pin-inner".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn observe(&mut self, obs: Observation, _view: &dyn PolicyView, out: &mut Vec<Action>) {
            self.seen += 1;
            if let Observation::Arrival { req } = obs {
                out.push(Action::Dispatch {
                    req,
                    to: Target::Worker(self.to),
                });
            }
        }
    }

    /// View with a configurable clock and worker roster.
    struct TestView {
        now: f64,
        workers: Vec<WorkerObs>,
    }

    impl TestView {
        fn at(now: f64) -> Self {
            TestView {
                now,
                workers: Vec::new(),
            }
        }

        fn with_idle(mut self, id: u32, kind: WorkerKind) -> Self {
            self.workers.push(WorkerObs {
                id: WorkerId(id),
                kind,
                state: WorkerState::Active,
                ready_at: 0.0,
                busy_until: 0.0,
                queued: 0,
                idle_since: self.now,
            });
            self
        }
    }

    impl PolicyView for TestView {
        fn now(&self) -> f64 {
            self.now
        }
        fn trace_live(&self) -> bool {
            true
        }
        fn service_time(&self, kind: WorkerKind, size: f64) -> f64 {
            match kind {
                WorkerKind::Cpu => size,
                WorkerKind::Fpga => size * 0.5,
            }
        }
        fn allocated(&self, kind: WorkerKind) -> u32 {
            self.workers.iter().filter(|w| w.kind == kind).count() as u32
        }
        fn live_ids(&self, kind: WorkerKind) -> Vec<WorkerId> {
            self.workers
                .iter()
                .filter(|w| w.kind == kind)
                .map(|w| w.id)
                .collect()
        }
        fn worker(&self, id: WorkerId) -> Option<WorkerObs> {
            self.workers.iter().find(|w| w.id == id).copied()
        }
    }

    fn req(arrival: f64, size: f64, deadline: f64, attempt: u32) -> Request {
        Request {
            arrival,
            size,
            deadline,
            attempt,
        }
    }

    fn completion(worker: u32, r: Request) -> Observation {
        Observation::Completion {
            worker: WorkerId(worker),
            req: r,
        }
    }

    #[test]
    fn disabled_recovery_forwards_verbatim() {
        let mut inner = PinInner {
            to: WorkerId(0),
            seen: 0,
        };
        let mut rec = Recovery::new(&mut inner, RecoveryConfig::disabled());
        let view = TestView::at(1.0);
        let mut out = Vec::new();
        // A retry arrival reaches the inner policy untouched — no Defer,
        // no Abandon, no Timer.
        rec.observe(
            Observation::Arrival {
                req: req(0.0, 1.0, 0.5, 2),
            },
            &view,
            &mut out,
        );
        assert!(
            matches!(out.as_slice(), [Action::Dispatch { req, .. }] if req.attempt == 2),
            "disabled layer must forward verbatim, got {out:?}"
        );
        assert_eq!(inner.seen, 1);
        assert_eq!(rec.name(), "pin-inner");
    }

    #[test]
    fn retry_backoff_defers_and_caps() {
        let mut inner = PinInner {
            to: WorkerId(0),
            seen: 0,
        };
        let cfg = RecoveryConfig::for_scenario(&ScenarioConfig::severe());
        let base = cfg.backoff_base;
        let cap = cfg.backoff_cap;
        let mut rec = Recovery::new(&mut inner, cfg);
        let view = TestView::at(10.0);

        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(9.0, 1.0, 100.0, 1),
            },
            &view,
            &mut out,
        );
        match out.as_slice() {
            [Action::Defer { until, .. }] => assert!((until - (10.0 + base)).abs() < 1e-12),
            other => panic!("attempt 1 must defer by base, got {other:?}"),
        }

        // A deep retry's backoff is clamped at the cap.
        out.clear();
        let deep = req(9.0, 1.0, 100.0, 3.min(rec.cfg.retry_budget));
        rec.observe(Observation::Arrival { req: deep }, &view, &mut out);
        match out.as_slice() {
            [Action::Defer { until, .. }] => {
                assert!(
                    *until <= 10.0 + cap + 1e-12,
                    "backoff must cap at {cap}, got {}",
                    until - 10.0
                );
            }
            other => panic!("deep retry must defer, got {other:?}"),
        }
        // The inner policy saw none of it.
        assert_eq!(inner.seen, 0);
    }

    #[test]
    fn infeasible_retry_is_abandoned_not_deferred() {
        let mut inner = PinInner {
            to: WorkerId(0),
            seen: 0,
        };
        let mut rec =
            Recovery::new(&mut inner, RecoveryConfig::for_scenario(&ScenarioConfig::severe()));
        // Fastest kind needs 0.5s for size 1.0; deadline is 0.2s away —
        // now + backoff + min_svc > deadline ⇒ abandon.
        let view = TestView::at(10.0);
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(9.0, 1.0, 10.2, 1),
            },
            &view,
            &mut out,
        );
        assert!(
            matches!(out.as_slice(), [Action::Abandon { .. }]),
            "infeasible retry must abandon, got {out:?}"
        );

        // Over-budget retries abandon regardless of deadline slack.
        out.clear();
        let over = req(9.0, 1.0, 1.0e9, rec.cfg.retry_budget + 1);
        rec.observe(Observation::Arrival { req: over }, &view, &mut out);
        assert!(matches!(out.as_slice(), [Action::Abandon { .. }]));
    }

    #[test]
    fn retry_due_reaches_inner_as_arrival() {
        let mut inner = PinInner {
            to: WorkerId(0),
            seen: 0,
        };
        let mut rec =
            Recovery::new(&mut inner, RecoveryConfig::for_scenario(&ScenarioConfig::severe()));
        let view = TestView::at(10.0).with_idle(0, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(
            Observation::RetryDue {
                req: req(9.0, 1.0, 100.0, 1),
            },
            &view,
            &mut out,
        );
        assert!(
            matches!(out.as_slice(), [Action::Dispatch { req, .. }] if req.attempt == 1),
            "matured retry must be offered to the inner policy, got {out:?}"
        );
        assert_eq!(inner.seen, 1);
    }

    #[test]
    fn breaker_opens_after_exactly_k_misses_and_probes_back() {
        let mut inner = PinInner {
            to: WorkerId(7),
            seen: 0,
        };
        let cfg = RecoveryConfig::for_scenario(&ScenarioConfig::severe());
        let k = cfg.breaker_k;
        let cooldown = cfg.breaker_cooldown;
        let mut rec = Recovery::new(&mut inner, cfg);

        // K-1 consecutive misses: no quarantine yet.
        for i in 0..k - 1 {
            let mut out = Vec::new();
            let view = TestView::at(100.0 + i as f64);
            rec.observe(completion(7, req(0.0, 1.0, 50.0, 0)), &view, &mut out);
            assert!(
                !out.iter().any(|a| matches!(a, Action::Quarantine { .. })),
                "breaker must not open before miss {k}, got {out:?}"
            );
        }
        // The K-th consecutive miss opens the breaker — exactly once.
        let mut out = Vec::new();
        let t_open = 100.0 + (k - 1) as f64;
        rec.observe(completion(7, req(0.0, 1.0, 50.0, 0)), &TestView::at(t_open), &mut out);
        assert_eq!(
            out.iter()
                .filter(|a| matches!(a, Action::Quarantine { worker } if *worker == WorkerId(7)))
                .count(),
            1,
            "miss #{k} must quarantine worker 7, got {out:?}"
        );

        // While quarantined, dispatches to 7 are rerouted to a healthy
        // idle worker.
        let view = TestView::at(t_open + 1.0).with_idle(3, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(t_open + 1.0, 1.0, t_open + 100.0, 0),
            },
            &view,
            &mut out,
        );
        assert!(
            matches!(out.first(), Some(Action::Dispatch { to: Target::Worker(w), .. }) if *w == WorkerId(3)),
            "quarantined target must be rerouted, got {out:?}"
        );

        // After the cool-down the next dispatch probes through to 7.
        let t_probe = t_open + cooldown + 1.0;
        let view = TestView::at(t_probe).with_idle(3, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(t_probe, 1.0, t_probe + 100.0, 0),
            },
            &view,
            &mut out,
        );
        assert!(
            matches!(out.first(), Some(Action::Dispatch { to: Target::Worker(w), .. }) if *w == WorkerId(7)),
            "cooled-down breaker must admit a probe, got {out:?}"
        );

        // An on-time probe completion closes the breaker: dispatches flow
        // to 7 with no reroute and no new quarantine.
        let mut out = Vec::new();
        rec.observe(
            completion(7, req(t_probe, 1.0, t_probe + 100.0, 0)),
            &TestView::at(t_probe + 0.5),
            &mut out,
        );
        assert!(out.iter().all(|a| !matches!(a, Action::Quarantine { .. })));
        let view = TestView::at(t_probe + 1.0).with_idle(3, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(t_probe + 1.0, 1.0, t_probe + 100.0, 0),
            },
            &view,
            &mut out,
        );
        assert!(
            matches!(out.first(), Some(Action::Dispatch { to: Target::Worker(w), .. }) if *w == WorkerId(7)),
            "closed breaker must stop rerouting, got {out:?}"
        );
    }

    #[test]
    fn failed_probe_reopens_the_breaker() {
        let mut inner = PinInner {
            to: WorkerId(7),
            seen: 0,
        };
        let cfg = RecoveryConfig::for_scenario(&ScenarioConfig::severe());
        let k = cfg.breaker_k;
        let cooldown = cfg.breaker_cooldown;
        let mut rec = Recovery::new(&mut inner, cfg);
        for i in 0..k {
            let mut out = Vec::new();
            rec.observe(
                completion(7, req(0.0, 1.0, 50.0, 0)),
                &TestView::at(100.0 + i as f64),
                &mut out,
            );
        }
        // Probe through after cool-down, then miss: the breaker re-opens
        // (a fresh Quarantine action) and dispatches reroute again.
        let t_probe = 100.0 + k as f64 + cooldown;
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(t_probe, 1.0, t_probe + 100.0, 0),
            },
            &TestView::at(t_probe).with_idle(3, WorkerKind::Fpga),
            &mut out,
        );
        assert!(
            matches!(out.first(), Some(Action::Dispatch { to: Target::Worker(w), .. }) if *w == WorkerId(7))
        );
        let mut out = Vec::new();
        rec.observe(
            completion(7, req(t_probe, 1.0, t_probe + 0.1, 0)),
            &TestView::at(t_probe + 5.0),
            &mut out,
        );
        assert!(
            out.iter().any(|a| matches!(a, Action::Quarantine { worker } if *worker == WorkerId(7))),
            "failed probe must re-open the breaker, got {out:?}"
        );
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(t_probe + 6.0, 1.0, t_probe + 100.0, 0),
            },
            &TestView::at(t_probe + 6.0).with_idle(3, WorkerKind::Fpga),
            &mut out,
        );
        assert!(
            matches!(out.first(), Some(Action::Dispatch { to: Target::Worker(w), .. }) if *w == WorkerId(3)),
            "re-opened breaker must reroute again, got {out:?}"
        );
    }

    #[test]
    fn hedge_arms_after_warmup_and_fires_only_while_live() {
        let mut inner = PinInner {
            to: WorkerId(0),
            seen: 0,
        };
        let cfg = RecoveryConfig::for_scenario(&ScenarioConfig::severe());
        let min_samples = cfg.hedge_min_samples;
        let mut rec = Recovery::new(&mut inner, cfg);

        // Cold layer: fresh dispatches arm no timers.
        let view = TestView::at(0.0).with_idle(0, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(
            Observation::Arrival {
                req: req(0.0, 1.0, 100.0, 0),
            },
            &view,
            &mut out,
        );
        assert!(
            !out.iter().any(|a| matches!(a, Action::Timer { .. })),
            "no hedging before warm-up, got {out:?}"
        );

        // Warm the latency histogram with on-time completions.
        for i in 0..min_samples {
            let t = 1.0 + i as f64 * 0.001;
            let mut out = Vec::new();
            rec.observe(
                completion(0, req(t - 0.0005, 1.0, t + 100.0, 0)),
                &TestView::at(t),
                &mut out,
            );
        }

        // A fresh dispatch now arms a hedge timer.
        let t0 = 50.0;
        let fresh = req(t0, 1.0, t0 + 100.0, 0);
        let view = TestView::at(t0).with_idle(0, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(Observation::Arrival { req: fresh }, &view, &mut out);
        let token = out
            .iter()
            .find_map(|a| match a {
                Action::Timer { at, token } => {
                    assert!(*at > t0, "hedge timer must be in the future");
                    Some(*token)
                }
                _ => None,
            })
            .expect("warmed-up dispatch must arm a hedge timer");

        // Timer fires while the request is still live and an idle spare
        // exists ⇒ hedge to the spare.
        let view = TestView::at(t0 + 10.0)
            .with_idle(5, WorkerKind::Fpga)
            .with_idle(6, WorkerKind::Cpu);
        let mut out = Vec::new();
        rec.observe(Observation::Timer { token }, &view, &mut out);
        assert!(
            matches!(out.as_slice(), [Action::Hedge { to: Target::Worker(w), .. }] if *w == WorkerId(5)),
            "live straggler must hedge to the idle FPGA, got {out:?}"
        );

        // Re-dispatch the same request shape; complete it before its
        // timer fires ⇒ the timer is a no-op.
        let t1 = 60.0;
        let fresh2 = req(t1, 1.0, t1 + 100.0, 0);
        let view = TestView::at(t1).with_idle(0, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(Observation::Arrival { req: fresh2 }, &view, &mut out);
        let token2 = out
            .iter()
            .find_map(|a| match a {
                Action::Timer { token, .. } => Some(*token),
                _ => None,
            })
            .expect("second dispatch must arm a timer");
        let mut out = Vec::new();
        rec.observe(completion(0, fresh2), &TestView::at(t1 + 0.4), &mut out);
        let view = TestView::at(t1 + 10.0).with_idle(5, WorkerKind::Fpga);
        let mut out = Vec::new();
        rec.observe(Observation::Timer { token: token2 }, &view, &mut out);
        assert!(
            out.is_empty(),
            "completed request must not hedge, got {out:?}"
        );
    }
}
