//! The real-time driver: any [`Policy`] driving *real compiled compute*.
//!
//! Where `sim/` evaluates scheduling policy at scale, `serve/` is the
//! end-to-end system a deployment would run. Both are drivers of the same
//! transport-agnostic policy core: the router paces the shared
//! [`sim::Driver`] stepping loop against the wall clock (a time-scale
//! factor compresses the paper's worker timings — 10 s FPGA spin-up →
//! 0.5 s wall at scale 20) and mirrors every applied [`Effect`] onto a
//! warm pool of worker threads. Worker threads own PJRT executables
//! compiled from the AOT artifacts ("FPGA" workers run the Pallas build,
//! CPU workers the jnp build) and dynamically batch requests.
//!
//! Because the decision loop *is* the sim driver, served behavior equals
//! simulated behavior action-for-action (pinned by
//! `rust/tests/policy_parity.rs`), and every Table 8 scheduler kind runs
//! under `spork serve --scheduler <kind>`. The router also inherits the
//! sim driver's indexed dispatch for free (DESIGN.md §3.1): policies
//! query the shared pool's ordered indexes through `PolicyView`, so
//! per-request routing cost is O(log W) in warm-pool size — the serving
//! hot path never scans the fleet. Energy and cost integrate
//! Table 6 powers/prices over *simulated* time through the same
//! accounting as the simulator; latencies and deadline misses come from
//! the real completion timestamps.
//!
//! Worker threads are compiled once into a **warm pool** (the pre-flashed
//! bitstream library analog — host-side XLA compile time must not leak
//! into the modeled dynamics) and cycle between parked and active;
//! activation pays the scaled Table 6 spin-up before serving.

mod admission;
mod chaos;
mod recovery;
mod shard;
mod worker;

pub use admission::Backpressure;
pub use chaos::{combine_digest, ChaosPlanInfo, ChaosSpec};
pub use recovery::{Recovery, RecoveryConfig};
pub use shard::{run_serve_sharded, AppFactory, AppServe};
pub use worker::{drain_completions, spawn_worker, Completion, Job, WorkerMsg};

use crate::cli::Args;
use crate::config::{PlatformConfig, SchedulerKind, SimConfig, WorkerKind};
use crate::policy::{Effect, Policy, WorkerId};
use crate::sched::breakeven::{breakeven_fpga_seconds, needed_fpgas, Objective};
use crate::sim::Driver;
use crate::trace::{synthetic_app_dt, AppTrace, ArrivalSource};
use crate::util::rng::Rng;
use crate::util::stats::LogHistogram;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What executes dispatched requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compute {
    /// The warm PJRT worker-thread pool, paced in scaled wall-clock time
    /// (requires compiled artifacts).
    Real,
    /// No threads, no artifacts, no pacing: the router steps the driver
    /// as fast as possible and reports the model-side accounting. Used by
    /// `spork serve --dry-run`, CI, and the driver-parity suite.
    Stub,
    /// Wall-clock pacing with stubbed execution: the router runs its full
    /// real-time loop — absolute-deadline sleeps, batched admission
    /// drains, replay-lag accounting — but no worker threads or artifacts.
    /// This is what `spork bench-serve` measures: router line rate and
    /// replay fidelity, isolated from PJRT execution.
    Paced,
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub platform: PlatformConfig,
    /// Simulated seconds per wall second.
    pub time_scale: f64,
    /// Request batch the worker executable accepts (8 or 32).
    pub batch: usize,
    pub deadline_factor: f64,
    /// Warm pool sizes (max concurrently active workers per kind).
    /// `0` = derive from the trace's interval demand via the breakeven
    /// rounding rule (see [`derive_pools`]).
    pub pool_cpus: usize,
    pub pool_fpgas: usize,
    /// Bounded admission: shed fresh arrivals while the fleet's in-flight
    /// backlog is at or above this many requests ([`Backpressure`]).
    /// `0` = unbounded (never shed) — the historical behavior, and
    /// bit-identical to it.
    pub queue_cap: usize,
    /// Chaos injection: replay this fault pack against the serving run
    /// at paced wall times and arm the [`Recovery`] layer (retry/backoff,
    /// hedging, circuit breaker). `None` = no chaos, no recovery — and
    /// bit-identical reports/effects to the pre-chaos serve path.
    pub chaos: Option<ChaosSpec>,
    /// Shutdown-drain grace, wall seconds: how long the router waits for
    /// straggling physical completions after sending `Shutdown`. A stalled
    /// or wedged worker thread can delay shutdown by at most this long —
    /// its missing completions are counted, not waited for.
    pub drain_grace_wall: f64,
}

impl ServeConfig {
    pub fn defaults(artifacts_dir: &str, time_scale: f64) -> Self {
        Self {
            artifacts_dir: artifacts_dir.to_string(),
            platform: PlatformConfig::paper_default(),
            time_scale,
            batch: 8,
            deadline_factor: 10.0,
            pool_cpus: 0,
            pool_fpgas: 0,
            queue_cap: 0,
            chaos: None,
            drain_grace_wall: 5.0,
        }
    }

    /// Pool sizes with zeros resolved from `trace` demand.
    pub fn resolved_pools(&self, trace: &AppTrace) -> (usize, usize) {
        if self.pool_cpus > 0 && self.pool_fpgas > 0 {
            return (self.pool_cpus, self.pool_fpgas);
        }
        let (auto_cpus, auto_fpgas) = derive_pools(&self.platform, trace);
        (
            if self.pool_cpus > 0 { self.pool_cpus } else { auto_cpus },
            if self.pool_fpgas > 0 { self.pool_fpgas } else { auto_fpgas },
        )
    }

    /// The simulation config the router's decision core runs under: the
    /// paper's derived interval/timeouts for this platform, with the warm
    /// pool sizes as worker caps.
    pub fn sim_config(&self, pool_cpus: usize, pool_fpgas: usize) -> SimConfig {
        let mut cfg = SimConfig::from_platform(self.platform.clone());
        cfg.deadline_factor = self.deadline_factor;
        cfg.max_cpus = Some(pool_cpus as u32);
        cfg.max_fpgas = Some(pool_fpgas as u32);
        cfg
    }
}

/// Derive warm pool sizes from trace demand: the FPGA pool covers the
/// peak per-interval needed-FPGA count (breakeven-rounded, like the
/// oracle baselines) plus one for prediction overshoot; the CPU pool can
/// absorb one peak interval's demand on the burst path (each FPGA-second
/// is `speedup` CPU-seconds) plus slack for spin-up shadows.
pub fn derive_pools(platform: &PlatformConfig, trace: &AppTrace) -> (usize, usize) {
    let interval = platform.fpga.spin_up;
    let speedup = platform.fpga.speedup;
    let tb = breakeven_fpga_seconds(platform, interval, Objective::energy());
    let peak = trace
        .work_per_interval(interval)
        .iter()
        .map(|w| needed_fpgas(w / speedup, interval, tb))
        .max()
        .unwrap_or(0);
    let fpgas = (peak + 1).max(2) as usize;
    let cpus = ((peak.max(1) as f64 * speedup).ceil() as usize + 2).max(4);
    (cpus, fpgas)
}

/// Outcome of a serving run (simulated-time units).
#[derive(Debug, Default)]
pub struct ServeReport {
    pub scheduler: String,
    pub requests: u64,
    pub on_cpu: u64,
    pub on_fpga: u64,
    pub misses: u64,
    /// Arrivals refused admission under backpressure (`queue_cap`);
    /// conserved with the rest (the extended conservation law):
    /// `requests == completions + shed + abandoned`.
    pub shed: u64,
    /// Requests that finished (winning hedge copies count once).
    pub completions: u64,
    /// Requests given up for good — retry budget or deadline exhausted
    /// after a kill, or an explicit recovery abandon. Each is also a
    /// deadline miss.
    pub abandoned: u64,
    /// Re-dispatches after preemptions/failures (extra attempts, not new
    /// requests — they never inflate `requests`).
    pub retries: u64,
    /// Duplicate dispatches launched by the recovery layer's hedging.
    pub hedges: u64,
    /// Hedged pairs won by the duplicate (`hedge_wins <= hedges`).
    pub hedge_wins: u64,
    /// Circuit-breaker openings (re-opening after a failed probe counts
    /// again).
    pub quarantines: u64,
    /// On-time completions that needed recovery help (a retried attempt
    /// or a hedged request finishing within deadline).
    pub recovered_deadline_hits: u64,
    /// Chaos spot-preemption kills applied to live workers.
    pub preemptions: u64,
    /// Chaos hardware-failure kills applied to live workers.
    pub worker_failures: u64,
    /// Physical completion records never received at shutdown (wedged or
    /// drop-injected workers, real compute only): `jobs sent − records
    /// drained` when the drain grace expires, 0 on a clean drain.
    pub completions_dropped: u64,
    /// The fault plan this run replayed (empty pack name = no chaos).
    pub chaos: ChaosPlanInfo,
    pub fpga_spinups: u64,
    pub cpu_spinups: u64,
    pub energy_j: f64,
    pub cost_usd: f64,
    /// Per-request latencies in a fixed-bin log histogram: memory is
    /// bounded (≈1.2k bins) at any request count, percentiles to p999
    /// within the bin growth factor (≤2% relative error).
    pub latency_ms: LogHistogram,
    pub wall_seconds: f64,
    pub sim_seconds: f64,
    /// Worst observed replay lag in wall seconds: how far behind its
    /// absolute pacing deadline the router woke. Small values are OS
    /// scheduling jitter; sustained growth means the host can't keep this
    /// time-scale. 0 under [`Compute::Stub`] (no pacing).
    pub max_lag_wall: f64,
    /// Completions whose batch's real PJRT execution ran past its scaled
    /// service budget (see [`Completion::overrun_wall`]); 0 without real
    /// compute.
    pub exec_overruns: u64,
    /// Largest single overrun, wall seconds.
    pub max_overrun_wall: f64,
    /// Sum of first output elements (sanity: real compute happened;
    /// 0 under stubbed compute).
    pub output_checksum: f64,
}

impl ServeReport {
    pub fn throughput(&self) -> f64 {
        if self.sim_seconds > 0.0 {
            self.requests as f64 / self.sim_seconds
        } else {
            0.0
        }
    }

    pub fn render(&mut self) -> String {
        let mut s = String::new();
        s.push_str(&format!("scheduler        : {}\n", self.scheduler));
        s.push_str(&format!(
            "served           : {} requests in {:.1} sim-s ({:.1} wall-s) = {:.0} req/s (sim)\n",
            self.requests,
            self.sim_seconds,
            self.wall_seconds,
            self.throughput()
        ));
        s.push_str(&format!(
            "split            : {} on FPGA ({:.1}%), {} on CPU\n",
            self.on_fpga,
            100.0 * self.on_fpga as f64 / self.requests.max(1) as f64,
            self.on_cpu
        ));
        if !self.latency_ms.is_empty() {
            s.push_str(&format!(
                "latency (sim ms) : p50 {:.1}  p95 {:.1}  p99 {:.1}  p999 {:.1}  max {:.1}\n",
                self.latency_ms.percentile(50.0),
                self.latency_ms.percentile(95.0),
                self.latency_ms.percentile(99.0),
                self.latency_ms.percentile(99.9),
                self.latency_ms.max()
            ));
        }
        s.push_str(&format!(
            "deadline misses  : {} ({:.2}%)\n",
            self.misses,
            100.0 * self.misses as f64 / self.requests.max(1) as f64
        ));
        if self.shed > 0 {
            s.push_str(&format!(
                "shed             : {} ({:.2}% of arrivals, queue cap backpressure)\n",
                self.shed,
                100.0 * self.shed as f64 / self.requests.max(1) as f64
            ));
        }
        // Chaos/recovery lines only appear when a pack was attached, so a
        // chaos-free report renders byte-identically to the pre-chaos one.
        if !self.chaos.pack.is_empty() {
            s.push_str(&format!(
                "chaos            : pack {} (seeds {}/{}), plan {:016x}: \
                 {} price ticks, {} preemptions, {} failures planned\n",
                self.chaos.pack,
                self.chaos.seed_base,
                self.chaos.seed,
                self.chaos.digest,
                self.chaos.price_ticks,
                self.chaos.preemptions,
                self.chaos.failures
            ));
            s.push_str(&format!(
                "faults applied   : {} preemptions, {} worker failures, \
                 {} retries, {} abandoned\n",
                self.preemptions, self.worker_failures, self.retries, self.abandoned
            ));
            s.push_str(&format!(
                "recovery         : {} hedges ({} won), {} quarantines, \
                 {} recovered deadline hits\n",
                self.hedges, self.hedge_wins, self.quarantines, self.recovered_deadline_hits
            ));
        }
        if self.completions_dropped > 0 {
            s.push_str(&format!(
                "dropped records  : {} physical completions never reported \
                 (wedged workers; drain grace expired)\n",
                self.completions_dropped
            ));
        }
        if self.max_lag_wall > 0.0 {
            s.push_str(&format!(
                "max replay lag   : {:.3} wall-s\n",
                self.max_lag_wall
            ));
        }
        if self.exec_overruns > 0 {
            s.push_str(&format!(
                "exec overruns    : {} batches over budget (worst {:.3} wall-s) — \
                 time-scale too aggressive for this host\n",
                self.exec_overruns, self.max_overrun_wall
            ));
        }
        s.push_str(&format!(
            "spin-ups         : {} fpga, {} cpu\n",
            self.fpga_spinups, self.cpu_spinups
        ));
        s.push_str(&format!(
            "energy / cost    : {:.1} J, ${:.5} (simulated, Table 6 powers)\n",
            self.energy_j, self.cost_usd
        ));
        s.push_str(&format!("output checksum  : {:.3}\n", self.output_checksum));
        s
    }
}

/// Run the hybrid serving loop over a trace with the default policy
/// (SporkE) and real compute.
pub fn run_serve(
    cfg: &ServeConfig,
    trace: &AppTrace,
    rng: &mut Rng,
) -> anyhow::Result<ServeReport> {
    run_serve_trace(cfg, trace, rng).map(|(r, _)| r)
}

/// Like [`run_serve`] but also returns the raw completion records
/// (diagnostics, tests, examples).
pub fn run_serve_trace(
    cfg: &ServeConfig,
    trace: &AppTrace,
    rng: &mut Rng,
) -> anyhow::Result<(ServeReport, Vec<Completion>)> {
    let (pool_cpus, pool_fpgas) = cfg.resolved_pools(trace);
    let sim_cfg = cfg.sim_config(pool_cpus, pool_fpgas);
    let mut policy = crate::sched::build(&SchedulerKind::spork_e(), &sim_cfg, trace);
    run_serve_policy(cfg, policy.as_mut(), trace, rng, Compute::Real, &mut |_| {})
}

/// Run any policy through the real-time driver: step the shared decision
/// core ([`sim::Driver`]) at wall-clock pace and mirror its effects onto
/// the warm worker-thread pool. Every applied [`Effect`] is also forwarded
/// to `sink` (the parity suite's audit stream).
pub fn run_serve_policy(
    cfg: &ServeConfig,
    policy: &mut dyn Policy,
    trace: &AppTrace,
    rng: &mut Rng,
    compute: Compute,
    sink: &mut dyn FnMut(&Effect),
) -> anyhow::Result<(ServeReport, Vec<Completion>)> {
    let (pool_cpus, pool_fpgas) = cfg.resolved_pools(trace);
    run_serve_source(
        cfg,
        policy,
        Box::new(trace.source()),
        pool_cpus,
        pool_fpgas,
        rng,
        compute,
        sink,
    )
}

/// [`run_serve_policy`] over a streaming arrival source: router memory is
/// bounded by the warm pool + in-flight work, never by stream length —
/// the serving path for endless or million-request request streams.
/// Pool sizes must be given explicitly (deriving them from demand needs a
/// full pass over the workload; see [`derive_pools`] for materialized
/// traces, or size from capacity planning).
#[allow(clippy::too_many_arguments)]
pub fn run_serve_source<'a>(
    cfg: &ServeConfig,
    policy: &'a mut dyn Policy,
    source: Box<dyn ArrivalSource + 'a>,
    pool_cpus: usize,
    pool_fpgas: usize,
    rng: &mut Rng,
    compute: Compute,
    sink: &mut dyn FnMut(&Effect),
) -> anyhow::Result<(ServeReport, Vec<Completion>)> {
    let scale = cfg.time_scale;
    let real = compute == Compute::Real;
    let paced = compute != Compute::Stub;
    let sim_cfg = cfg.sim_config(pool_cpus, pool_fpgas);
    let platform = sim_cfg.platform.clone();
    if let Some(c) = &cfg.chaos {
        c.validate().map_err(|e| anyhow::anyhow!(e))?;
    }

    // Build the warm pool (compile once; threads park), or skip it
    // entirely under stubbed compute.
    let (done_tx, done_rx) = mpsc::channel::<Completion>();
    let mut phys: Vec<(WorkerKind, mpsc::Sender<WorkerMsg>)> = Vec::new();
    if real {
        let (ready_tx, ready_rx) = mpsc::channel::<()>();
        for (kind, count) in [
            (WorkerKind::Fpga, pool_fpgas),
            (WorkerKind::Cpu, pool_cpus),
        ] {
            for _ in 0..count {
                let tx = spawn_worker(
                    kind,
                    cfg.artifacts_dir.clone(),
                    cfg.batch,
                    *platform.params(kind),
                    scale,
                    ready_tx.clone(),
                    done_tx.clone(),
                )?;
                phys.push((kind, tx));
            }
        }
        // Barrier: all executables compiled before the clock starts.
        drop(ready_tx);
        for _ in 0..phys.len() {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("a pool worker failed to initialize"))?;
        }
    }

    // Router-side binding of model workers to physical slots. The model
    // (the driver's pool, capped at the pool sizes) is authoritative:
    // allocation grabs a parked slot, retirement parks it again. Since
    // the caps equal the slot counts and a retired model worker unbinds
    // immediately, a parked slot always exists when allocation succeeds.
    let mut parked_fpga: Vec<usize> = Vec::new();
    let mut parked_cpu: Vec<usize> = Vec::new();
    for (i, (kind, _)) in phys.iter().enumerate() {
        match kind {
            WorkerKind::Fpga => parked_fpga.push(i),
            WorkerKind::Cpu => parked_cpu.push(i),
        }
    }
    let mut bind: HashMap<WorkerId, usize> = HashMap::new();
    let mut job_id = 0u64;
    let d_in = 128usize;
    let epoch = Instant::now();

    // Decorator chain: driver → Backpressure (outer) → Recovery → policy.
    // Shedding stays outermost (an at-cap arrival never reaches recovery);
    // deferred retries re-enter as non-arrival observations the admission
    // layer forwards verbatim. Without a chaos pack the recovery layer is
    // disabled and both wrappers are inert (bit-identical observations).
    let rcfg = cfg
        .chaos
        .as_ref()
        .map(|c| RecoveryConfig::for_scenario(&c.scenario))
        .unwrap_or_else(RecoveryConfig::disabled);
    let mut recovery = Recovery::new(policy, rcfg);
    let mut policy = Backpressure::new(&mut recovery, cfg.queue_cap as u64);
    let mut driver = Driver::from_source(source, sim_cfg, &mut policy);
    // Replay contract: the plan's faults enter the shared event heap here,
    // and the pacing loop below fires each at its scaled wall time.
    let chaos_plan = cfg
        .chaos
        .as_ref()
        .map(|c| driver.attach_scenario(&c.scenario, c.seed_base, c.seed));
    let mut latency = LogHistogram::latency_ms();
    let mut max_lag_wall = 0.0f64;
    // Wall-side exec injection (real compute under chaos): per applied
    // kill, stall one surviving worker's next batch and optionally drop
    // its completion records.
    let wall_inject = cfg
        .chaos
        .as_ref()
        .filter(|c| c.stall_wall > 0.0)
        .map(|c| (c.stall_wall, c.drop_completions));
    {
        let mut handle = |e: &Effect| {
            if real {
                match *e {
                    Effect::Allocated { worker, kind, prewarmed } => {
                        let parked = match kind {
                            WorkerKind::Fpga => &mut parked_fpga,
                            WorkerKind::Cpu => &mut parked_cpu,
                        };
                        if let Some(slot) = parked.pop() {
                            let spin_up = if prewarmed {
                                0.0
                            } else {
                                platform.params(kind).spin_up
                            };
                            let _ = phys[slot].1.send(WorkerMsg::Activate { epoch, spin_up });
                            bind.insert(worker, slot);
                        }
                    }
                    Effect::Dispatched { worker, arrival, size, deadline, .. } => {
                        if let Some(&slot) = bind.get(&worker) {
                            job_id += 1;
                            let input: Vec<f32> =
                                (0..d_in).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
                            let _ = phys[slot].1.send(WorkerMsg::Job(Job {
                                id: job_id,
                                input,
                                arrival_sim: arrival,
                                deadline_sim: deadline,
                                size,
                            }));
                        }
                    }
                    Effect::Retired { worker, kind } => {
                        if let Some(slot) = bind.remove(&worker) {
                            let _ = phys[slot].1.send(WorkerMsg::Park);
                            match kind {
                                WorkerKind::Fpga => parked_fpga.push(slot),
                                WorkerKind::Cpu => parked_cpu.push(slot),
                            }
                        }
                    }
                    Effect::Killed { worker, kind, .. } => {
                        // A kill is a retirement from the physical pool's
                        // point of view: the slot parks and can be re-bound
                        // by a later allocation (the replacement worker).
                        if let Some(slot) = bind.remove(&worker) {
                            let _ = phys[slot].1.send(WorkerMsg::Park);
                            match kind {
                                WorkerKind::Fpga => parked_fpga.push(slot),
                                WorkerKind::Cpu => parked_cpu.push(slot),
                            }
                        }
                        // Wall-side chaos: each applied kill also stalls
                        // the lowest surviving bound slot's next batch
                        // (deterministic pick) — a slowdown the exec-
                        // overrun accounting observes, with optional
                        // completion-record loss the drain grace surfaces
                        // as `completions_dropped` instead of a hang.
                        if let Some((stall_wall, drop_batch)) = wall_inject {
                            if let Some(&slot) = bind.values().min() {
                                let _ = phys[slot].1.send(WorkerMsg::Inject {
                                    stall_wall,
                                    drop_batch,
                                });
                            }
                        }
                    }
                    Effect::KeptAlive { .. } => {}
                    // Nothing was dispatched — the client gets a fast
                    // load-shed rejection; no physical slot is involved.
                    Effect::Shed { .. } => {}
                    // Model-clock completion: physical completions arrive
                    // through the done channel; nothing to mirror.
                    Effect::Completed { .. } => {}
                    // Routing around the worker is the recovery layer's
                    // job; the slot stays bound and warm.
                    Effect::Quarantined { .. } => {}
                }
            } else if let Effect::Completed { arrival, finish, .. } = *e {
                // Stubbed execution: the model's completion time is the
                // truth, so every *completed* request contributes exactly
                // one latency (full coverage, unlike the sim metrics'
                // subsample — and hedged pairs book only the winning
                // copy). On the fault-free path this records the same
                // (arrival, finish) multiset the dispatch stream carries,
                // so chaos-off reports stay bit-identical.
                latency.add((finish - arrival) * 1000.0);
            }
            sink(e);
        };

        driver.start(&mut handle);
        while let Some(t) = driver.next_time() {
            if paced {
                // Drift-free pacing: sleep to the *absolute* wall deadline
                // of the next occurrence (epoch-anchored), never by a
                // relative delta — per-iteration sleep error cannot
                // accumulate across a long replay.
                let target = epoch + Duration::from_secs_f64(t / scale);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let elapsed = epoch.elapsed().as_secs_f64();
                max_lag_wall = max_lag_wall.max(elapsed - t / scale);
                // Batched admission: one wakeup drains every occurrence
                // that became due during this pacing quantum, in exactly
                // per-step order (the `.max(t)` guarantees progress even
                // if the sleep undershot by a rounding ulp). Amortizes
                // clock reads and sleep syscalls over the whole burst.
                driver.step_until((elapsed * scale).max(t), &mut handle);
            } else {
                driver.step(&mut handle);
            }
        }
    }

    // The model pool has fully drained (every worker retired through its
    // idle timeout); shut the physical pool down and collect completions.
    let sim_end = driver.now();
    let result = driver.finish(&platform);
    for (_, tx) in &phys {
        let _ = tx.send(WorkerMsg::Shutdown);
    }
    drop(done_tx);
    // Grace-bounded drain: a permanently wedged worker thread (stalled
    // inside its executable, or holding its sender hostage) delays
    // shutdown by at most `drain_grace_wall` — its missing records are
    // counted below instead of blocking the router forever.
    let (completions, _drain_timed_out) =
        drain_completions(&done_rx, Duration::from_secs_f64(cfg.drain_grace_wall.max(0.0)));

    let m = &result.metrics;
    let mut report = ServeReport {
        scheduler: result.scheduler.clone(),
        requests: m.requests,
        on_cpu: m.on_cpu,
        on_fpga: m.on_fpga,
        shed: m.shed,
        completions: m.completions,
        abandoned: m.abandoned,
        retries: m.redispatches,
        hedges: m.hedges,
        hedge_wins: m.hedge_wins,
        quarantines: m.quarantines,
        recovered_deadline_hits: m.recovered_deadline_hits,
        preemptions: m.preemptions,
        worker_failures: m.worker_failures,
        fpga_spinups: m.fpga_spinups,
        cpu_spinups: m.cpu_spinups,
        energy_j: m.total_energy(),
        cost_usd: m.total_cost(),
        sim_seconds: sim_end,
        wall_seconds: epoch.elapsed().as_secs_f64(),
        max_lag_wall,
        ..Default::default()
    };
    if let (Some(c), Some(plan)) = (&cfg.chaos, &chaos_plan) {
        let counts = plan.counts();
        report.chaos = ChaosPlanInfo {
            pack: c.scenario.name.clone(),
            seed_base: c.seed_base,
            seed: c.seed,
            digest: plan.digest(),
            price_ticks: counts.price_ticks,
            preemptions: counts.preemptions,
            failures: counts.failures,
        };
    }
    if real {
        report.completions_dropped = job_id.saturating_sub(completions.len() as u64);
    }
    match compute {
        Compute::Real => {
            // End-to-end truth: latency and deadline behavior from the
            // physical completion timestamps.
            for c in &completions {
                if c.finish_sim > c.deadline_sim + 1e-9 {
                    report.misses += 1;
                }
                report.latency_ms.add((c.finish_sim - c.arrival_sim) * 1000.0);
                report.output_checksum += c.output0 as f64;
                if c.overrun_wall > 0.0 {
                    report.exec_overruns += 1;
                    report.max_overrun_wall = report.max_overrun_wall.max(c.overrun_wall);
                }
            }
        }
        Compute::Stub | Compute::Paced => {
            // Model-side accounting; latencies were collected per
            // dispatch in the effect handler (full coverage).
            report.misses = m.deadline_misses;
            report.latency_ms = latency;
        }
    }
    Ok((report, completions))
}

/// `spork serve` CLI entrypoint.
pub fn cmd_serve(args: &Args) -> Result<(), String> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let dry_run = args.has_flag("dry-run");
    if !dry_run && !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        return Err(format!(
            "artifacts not found at '{artifacts}' — run `make artifacts` first, \
             or pass --dry-run for stubbed compute"
        ));
    }
    let time_scale = args.f64_or("time-scale", 5.0)?;
    let rate = args.f64_or("rate", 40.0)?;
    let duration_wall = args.f64_or("duration", 20.0)?;
    let duration = duration_wall * time_scale;
    let burstiness = args.f64_or("burstiness", 0.65)?;
    let seed = args.u64_or("seed", 1)?;
    let sched_name = args.str_or("scheduler", "spork-e");
    let kind = SchedulerKind::from_name(&sched_name)
        .ok_or(format!("unknown scheduler '{sched_name}'"))?;

    let mut cfg = ServeConfig::defaults(&artifacts, time_scale);
    cfg.pool_cpus = args.usize_or("pool-cpus", 0)?;
    cfg.pool_fpgas = args.usize_or("pool-fpgas", 0)?;
    cfg.queue_cap = args.usize_or("queue-cap", 0)?;
    if let Some(pack) = args.get("chaos") {
        cfg.chaos = Some(
            ChaosSpec::from_name(pack, seed, 0)
                .ok_or(format!("unknown chaos pack '{pack}' (fault-free|mild|severe)"))?,
        );
    }

    let mut rng = Rng::new(seed);
    let trace = synthetic_app_dt("serve", &mut rng, burstiness, duration, rate, 0.010, 60.0);
    let (pool_cpus, pool_fpgas) = cfg.resolved_pools(&trace);
    cfg.pool_cpus = pool_cpus;
    cfg.pool_fpgas = pool_fpgas;
    let sim_cfg = cfg.sim_config(pool_cpus, pool_fpgas);
    let mut policy = crate::sched::build(&kind, &sim_cfg, &trace);
    println!(
        "serving {} requests over {:.0} simulated seconds with {} \
         ({pool_fpgas} fpga + {pool_cpus} cpu warm workers, {}x compression{})...",
        trace.len(),
        duration,
        kind.display(),
        time_scale,
        if dry_run { ", dry run" } else { "" }
    );
    let compute = if dry_run { Compute::Stub } else { Compute::Real };
    let (mut report, _) =
        run_serve_policy(&cfg, policy.as_mut(), &trace, &mut rng, compute, &mut |_| {})
            .map_err(|e| e.to_string())?;
    print!("{}", report.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Arrival;

    fn flat_trace(rate: f64, duration: f64, size: f64) -> AppTrace {
        let n = (rate * duration) as usize;
        let arrivals = (0..n)
            .map(|i| Arrival {
                time: i as f64 / rate,
                size,
            })
            .collect();
        AppTrace::new("flat", arrivals, duration)
    }

    #[test]
    fn derived_pools_track_demand() {
        let platform = PlatformConfig::paper_default();
        // 100 req/s x 10ms = 1 CPU-s/s = 5 FPGA-s/interval → 1 FPGA needed.
        let light = flat_trace(100.0, 60.0, 0.010);
        let (c1, f1) = derive_pools(&platform, &light);
        // 4000 req/s x 10ms = 40 CPU-s/s = 200 FPGA-s/interval → 20 FPGAs.
        let heavy = flat_trace(4000.0, 60.0, 0.010);
        let (c2, f2) = derive_pools(&platform, &heavy);
        assert!(f2 > f1, "fpga pool must scale with demand: {f1} vs {f2}");
        assert!(c2 > c1, "cpu pool must scale with demand: {c1} vs {c2}");
        assert_eq!(f2, 21); // peak 20 + 1 overshoot slack
    }

    #[test]
    fn config_resolution_respects_overrides() {
        let mut cfg = ServeConfig::defaults("x", 5.0);
        let trace = flat_trace(100.0, 60.0, 0.010);
        let (c, f) = cfg.resolved_pools(&trace);
        assert!(c >= 4 && f >= 2);
        cfg.pool_cpus = 9;
        cfg.pool_fpgas = 5;
        assert_eq!(cfg.resolved_pools(&trace), (9, 5));
        let sim_cfg = cfg.sim_config(9, 5);
        assert_eq!(sim_cfg.max_cpus, Some(9));
        assert_eq!(sim_cfg.max_fpgas, Some(5));
    }

    #[test]
    fn stub_serve_runs_every_table8_kind() {
        // The serve path must execute end-to-end (no artifacts needed)
        // for the full roster — the point of the policy-core redesign.
        let mut rng = Rng::new(5);
        let trace = crate::trace::synthetic_app("s", &mut rng, 0.6, 60.0, 40.0, 0.010);
        for kind in SchedulerKind::table8_roster() {
            let cfg = ServeConfig::defaults("unused", 1e9);
            let (pc, pf) = cfg.resolved_pools(&trace);
            let sim_cfg = cfg.sim_config(pc, pf);
            let mut policy = crate::sched::build(&kind, &sim_cfg, &trace);
            let mut rng2 = Rng::new(6);
            let (report, completions) = run_serve_policy(
                &cfg,
                policy.as_mut(),
                &trace,
                &mut rng2,
                Compute::Stub,
                &mut |_| {},
            )
            .unwrap();
            assert_eq!(
                report.requests as usize,
                trace.len(),
                "{} dropped requests under serve",
                kind.name()
            );
            assert!(completions.is_empty(), "stub compute must not execute");
            assert!(report.energy_j > 0.0);
            assert_eq!(report.scheduler, kind.name());
        }
    }
}
