//! The serving runtime: Spork driving *real compiled compute*.
//!
//! Where `sim/` evaluates scheduling policy at scale, `serve/` is the
//! end-to-end system a deployment would run: a router owns the Spork
//! dispatcher and per-interval FPGA allocator; worker threads own PJRT
//! executables compiled from the AOT artifacts ("FPGA" workers run the
//! Pallas build, CPU workers the jnp build) and dynamically batch
//! requests; a time-scale factor compresses the paper's worker timings
//! (10 s FPGA spin-up → 0.5 s wall at scale 20) so a multi-simulated-
//! minute run finishes in tens of wall seconds.
//!
//! Worker threads are compiled once into a **warm pool** (the pre-flashed
//! bitstream library analog — host-side XLA compile time must not leak
//! into the modeled dynamics) and cycle between parked and active;
//! activation pays the scaled Table 6 spin-up before serving. Energy and
//! cost integrate Table 6 powers/prices over *simulated* time.

mod worker;

pub use worker::{spawn_worker, Completion, Job, WorkerMsg};

use crate::cli::Args;
use crate::config::{PlatformConfig, WorkerKind};
use crate::sched::breakeven::{breakeven_fpga_seconds, needed_fpgas, Objective};
use crate::trace::{synthetic_app_dt, AppTrace};
use crate::util::rng::Rng;
use crate::util::stats::Sample;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub platform: PlatformConfig,
    /// Simulated seconds per wall second.
    pub time_scale: f64,
    /// Request batch the worker executable accepts (8 or 32).
    pub batch: usize,
    /// Simulated scheduling interval (= FPGA spin-up).
    pub interval: f64,
    pub deadline_factor: f64,
    pub idle_timeout: f64,
    /// Warm pool sizes (max concurrently active workers per kind).
    pub pool_cpus: usize,
    pub pool_fpgas: usize,
}

impl ServeConfig {
    pub fn defaults(artifacts_dir: &str, time_scale: f64) -> Self {
        let platform = PlatformConfig::paper_default();
        Self {
            artifacts_dir: artifacts_dir.to_string(),
            time_scale,
            batch: 8,
            interval: platform.fpga.spin_up,
            deadline_factor: 10.0,
            idle_timeout: platform.fpga.spin_up,
            pool_cpus: 6,
            pool_fpgas: 3,
            platform,
        }
    }
}

/// Outcome of a serving run (simulated-time units).
#[derive(Debug, Default)]
pub struct ServeReport {
    pub requests: u64,
    pub on_cpu: u64,
    pub on_fpga: u64,
    pub misses: u64,
    pub fpga_spinups: u64,
    pub cpu_spinups: u64,
    pub energy_j: f64,
    pub cost_usd: f64,
    pub latency_ms: Sample,
    pub wall_seconds: f64,
    pub sim_seconds: f64,
    /// Sum of first output elements (sanity: real compute happened).
    pub output_checksum: f64,
}

impl ServeReport {
    pub fn throughput(&self) -> f64 {
        if self.sim_seconds > 0.0 {
            self.requests as f64 / self.sim_seconds
        } else {
            0.0
        }
    }

    pub fn render(&mut self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "served           : {} requests in {:.1} sim-s ({:.1} wall-s) = {:.0} req/s (sim)\n",
            self.requests,
            self.sim_seconds,
            self.wall_seconds,
            self.throughput()
        ));
        s.push_str(&format!(
            "split            : {} on FPGA ({:.1}%), {} on CPU\n",
            self.on_fpga,
            100.0 * self.on_fpga as f64 / self.requests.max(1) as f64,
            self.on_cpu
        ));
        if !self.latency_ms.is_empty() {
            s.push_str(&format!(
                "latency (sim ms) : p50 {:.1}  p95 {:.1}  p99 {:.1}  max {:.1}\n",
                self.latency_ms.percentile(50.0),
                self.latency_ms.percentile(95.0),
                self.latency_ms.percentile(99.0),
                self.latency_ms.max()
            ));
        }
        s.push_str(&format!(
            "deadline misses  : {} ({:.2}%)\n",
            self.misses,
            100.0 * self.misses as f64 / self.requests.max(1) as f64
        ));
        s.push_str(&format!(
            "spin-ups         : {} fpga, {} cpu\n",
            self.fpga_spinups, self.cpu_spinups
        ));
        s.push_str(&format!(
            "energy / cost    : {:.1} J, ${:.5} (simulated, Table 6 powers)\n",
            self.energy_j, self.cost_usd
        ));
        s.push_str(&format!("output checksum  : {:.3}\n", self.output_checksum));
        s
    }
}

/// Router-side view of one warm worker.
struct Slot {
    kind: WorkerKind,
    tx: mpsc::Sender<WorkerMsg>,
    active: bool,
    /// Simulated times (router estimates).
    ready_at: f64,
    busy_until: f64,
    activated_at: f64,
    /// Accumulated simulated busy seconds in the current activation.
    busy_accum: f64,
}

/// Run the hybrid serving loop over a trace.
pub fn run_serve(cfg: &ServeConfig, trace: &AppTrace, rng: &mut Rng) -> anyhow::Result<ServeReport> {
    run_serve_trace(cfg, trace, rng).map(|(r, _)| r)
}

/// Like [`run_serve`] but also returns the raw completion records
/// (diagnostics, tests, examples).
pub fn run_serve_trace(
    cfg: &ServeConfig,
    trace: &AppTrace,
    rng: &mut Rng,
) -> anyhow::Result<(ServeReport, Vec<Completion>)> {
    let scale = cfg.time_scale;
    let (done_tx, done_rx) = mpsc::channel::<Completion>();
    let (ready_tx, ready_rx) = mpsc::channel::<()>();
    let mut report = ServeReport::default();

    // Build the warm pool (compile once; threads park).
    let mut slots: Vec<Slot> = Vec::new();
    for (kind, count) in [
        (WorkerKind::Fpga, cfg.pool_fpgas),
        (WorkerKind::Cpu, cfg.pool_cpus),
    ] {
        for _ in 0..count {
            let tx = spawn_worker(
                kind,
                cfg.artifacts_dir.clone(),
                cfg.batch,
                *cfg.platform.params(kind),
                scale,
                ready_tx.clone(),
                done_tx.clone(),
            )?;
            slots.push(Slot {
                kind,
                tx,
                active: false,
                ready_at: 0.0,
                busy_until: 0.0,
                activated_at: 0.0,
                busy_accum: 0.0,
            });
        }
    }
    // Barrier: all executables compiled before the clock starts.
    drop(ready_tx);
    for _ in 0..slots.len() {
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("a pool worker failed to initialize"))?;
    }
    let epoch = Instant::now();
    let sim_now = || epoch.elapsed().as_secs_f64() * scale;

    // Accounting helpers (energy/cost integrated on deactivation).
    fn deactivate(slot: &mut Slot, now: f64, platform: &PlatformConfig, report: &mut ServeReport) {
        if !slot.active {
            return;
        }
        let _ = slot.tx.send(WorkerMsg::Park);
        slot.active = false;
        let params = platform.params(slot.kind);
        let life = (now - slot.activated_at).max(0.0);
        let active_span = (now - slot.ready_at).max(0.0);
        let idle = (active_span - slot.busy_accum).max(0.0);
        report.energy_j += params.spin_up_energy()
            + params.spin_down_energy()
            + slot.busy_accum * params.busy_power
            + idle * params.idle_power;
        report.cost_usd += (life + params.spin_down) * params.cost_per_sec();
    }

    fn activate(
        slot: &mut Slot,
        now: f64,
        epoch: Instant,
        platform: &PlatformConfig,
        report: &mut ServeReport,
    ) {
        debug_assert!(!slot.active);
        let _ = slot.tx.send(WorkerMsg::Activate(epoch));
        slot.active = true;
        let params = platform.params(slot.kind);
        slot.activated_at = now;
        slot.ready_at = now + params.spin_up;
        slot.busy_until = slot.ready_at;
        slot.busy_accum = 0.0;
        match slot.kind {
            WorkerKind::Cpu => report.cpu_spinups += 1,
            WorkerKind::Fpga => report.fpga_spinups += 1,
        }
    }

    // Spork-style interval allocator state (last-value predictor; the full
    // conditional-histogram predictor lives in `sched::spork` — the
    // serving loop demonstrates the allocation/dispatch architecture).
    let breakeven = breakeven_fpga_seconds(&cfg.platform, cfg.interval, Objective::energy());
    let speedup = cfg.platform.fpga.speedup;
    let mut interval_work = (0.0f64, 0.0f64); // (cpu, fpga) service-seconds
    let mut next_tick = cfg.interval;

    let mut job_id = 0u64;
    let d_in = 128usize;
    let mut behind_warned = false;

    for arrival in &trace.arrivals {
        let target_wall = arrival.time / scale;
        let elapsed = epoch.elapsed().as_secs_f64();
        if target_wall > elapsed {
            std::thread::sleep(Duration::from_secs_f64(target_wall - elapsed));
        } else if elapsed - target_wall > 2.0 && !behind_warned {
            eprintln!(
                "warning: replay {:.1}s behind wall schedule (host overloaded?)",
                elapsed - target_wall
            );
            behind_warned = true;
        }
        let now = sim_now();

        // Interval tick: allocate FPGAs for observed demand; park idlers.
        while now >= next_tick {
            let lambda = interval_work.1 + interval_work.0 / speedup;
            interval_work = (0.0, 0.0);
            let needed = needed_fpgas(lambda, cfg.interval, breakeven) as usize;
            let active_fpgas = slots
                .iter()
                .filter(|s| s.active && s.kind == WorkerKind::Fpga)
                .count();
            if needed > active_fpgas {
                let mut to_add = needed - active_fpgas;
                for slot in slots.iter_mut() {
                    if to_add == 0 {
                        break;
                    }
                    if slot.kind == WorkerKind::Fpga && !slot.active {
                        activate(slot, now, epoch, &cfg.platform, &mut report);
                        to_add -= 1;
                    }
                }
            }
            // Idle reclamation (both kinds).
            for slot in slots.iter_mut() {
                if slot.active && now > slot.busy_until + cfg.idle_timeout {
                    deactivate(slot, now, &cfg.platform, &mut report);
                }
            }
            next_tick += cfg.interval;
        }

        // Dispatch: efficient-first (busiest feasible FPGA, then CPU),
        // reactive CPU activation as the burst path (Alg 3).
        let deadline = now + cfg.deadline_factor * arrival.size;
        let mut chosen: Option<usize> = None;
        for kind in [WorkerKind::Fpga, WorkerKind::Cpu] {
            let svc = arrival.size / cfg.platform.params(kind).speedup;
            let mut best: Option<(f64, usize)> = None;
            for (i, s) in slots.iter().enumerate() {
                if !s.active || s.kind != kind {
                    continue;
                }
                let finish = s.busy_until.max(now) + svc;
                if finish <= deadline && best.map_or(true, |(l, _)| s.busy_until > l) {
                    best = Some((s.busy_until, i));
                }
            }
            if let Some((_, i)) = best {
                chosen = Some(i);
                break;
            }
        }
        let widx = match chosen {
            None => {
                // Activate a parked CPU (5ms sim spin-up).
                let parked_cpu = slots
                    .iter()
                    .position(|s| !s.active && s.kind == WorkerKind::Cpu);
                match parked_cpu {
                    Some(i) => {
                        activate(&mut slots[i], now, epoch, &cfg.platform, &mut report);
                        i
                    }
                    None => {
                        // Pool exhausted: best-effort onto earliest finish.
                        slots
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| s.active)
                            .min_by(|a, b| {
                                a.1.busy_until.partial_cmp(&b.1.busy_until).unwrap()
                            })
                            .map(|(i, _)| i)
                            .expect("no active workers at dispatch")
                    }
                }
            }
            Some(i) => i,
        };
        let slot = &mut slots[widx];
        let svc = arrival.size / cfg.platform.params(slot.kind).speedup;
        slot.busy_until = slot.busy_until.max(now.max(slot.ready_at)) + svc;
        slot.busy_accum += svc;
        match slot.kind {
            WorkerKind::Cpu => interval_work.0 += svc,
            WorkerKind::Fpga => interval_work.1 += svc,
        }
        let input: Vec<f32> = (0..d_in).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        job_id += 1;
        let _ = slot.tx.send(WorkerMsg::Job(Job {
            id: job_id,
            input,
            arrival_sim: now,
            deadline_sim: deadline,
            size: arrival.size,
        }));
    }

    // Drain: deactivate everything, close channels, collect completions.
    let end_sim = sim_now();
    for slot in slots.iter_mut() {
        deactivate(slot, end_sim.max(slot.busy_until), &cfg.platform, &mut report);
        let _ = slot.tx.send(WorkerMsg::Shutdown);
    }
    drop(done_tx);
    let mut completions = Vec::new();
    while let Ok(c) = done_rx.recv() {
        report.requests += 1;
        match c.kind {
            WorkerKind::Cpu => report.on_cpu += 1,
            WorkerKind::Fpga => report.on_fpga += 1,
        }
        if c.finish_sim > c.deadline_sim + 1e-9 {
            report.misses += 1;
        }
        report.latency_ms.add((c.finish_sim - c.arrival_sim) * 1000.0);
        report.output_checksum += c.output0 as f64;
        completions.push(c);
    }
    report.wall_seconds = epoch.elapsed().as_secs_f64();
    report.sim_seconds = end_sim;
    Ok((report, completions))
}

/// `spork serve` CLI entrypoint.
pub fn cmd_serve(args: &Args) -> Result<(), String> {
    let artifacts = args.str_or("artifacts", "artifacts");
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        return Err(format!(
            "artifacts not found at '{artifacts}' — run `make artifacts` first"
        ));
    }
    let time_scale = args.f64_or("time-scale", 5.0)?;
    let rate = args.f64_or("rate", 40.0)?;
    let duration_wall = args.f64_or("duration", 20.0)?;
    let duration = duration_wall * time_scale;
    let burstiness = args.f64_or("burstiness", 0.65)?;
    let seed = args.u64_or("seed", 1)?;

    let cfg = ServeConfig::defaults(&artifacts, time_scale);
    let mut rng = Rng::new(seed);
    let trace = synthetic_app_dt("serve", &mut rng, burstiness, duration, rate, 0.010, 60.0);
    println!(
        "serving {} requests over {:.0} simulated seconds ({}x compression, ~{:.0}s wall)...",
        trace.len(),
        duration,
        time_scale,
        duration_wall
    );
    let mut report = run_serve(&cfg, &trace, &mut rng).map_err(|e| e.to_string())?;
    print!("{}", report.render());
    Ok(())
}
