//! Worker threads for the serving runtime.
//!
//! Each worker owns its own PJRT client + compiled executable (thread
//! confinement — the xla handles are not Send). Threads are created and
//! compiled **once** at pool construction (the pre-flashed bitstream
//! library / warm container image analog) and then cycle between *parked*
//! and *active*: activation sleeps the scaled Table 6 spin-up latency
//! before serving (reconfiguration), deactivation parks the thread again.
//! This keeps host-side compile cost out of the modeled dynamics — worker
//! timing is governed by the paper's parameters, not by XLA compile time.
//!
//! Requests are dynamically batched: a worker drains up to `batch` queued
//! jobs per execution and zero-pads the rest of the batch.

use crate::config::{WorkerKind, WorkerParams};
use crate::runtime::Runtime;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Job {
    pub id: u64,
    pub input: Vec<f32>,
    pub arrival_sim: f64,
    pub deadline_sim: f64,
    /// Request size in CPU-seconds (drives the emulated service time).
    pub size: f64,
}

#[derive(Debug)]
pub enum WorkerMsg {
    /// Begin serving after sleeping `spin_up` scaled simulated seconds
    /// (0 for pre-warmed workers). Carries the shared wall-clock origin
    /// for completion timestamps.
    Activate { epoch: Instant, spin_up: f64 },
    Job(Job),
    /// Stop serving and park (worker stays warm).
    Park,
    /// Exit the thread.
    Shutdown,
    /// Chaos (wall-side): stall the next batch by `stall_wall` wall
    /// seconds past its budget — the stall surfaces through the exec-
    /// overrun accounting — and, when `drop_batch`, swallow that batch's
    /// completion records entirely (a worker that wedges without
    /// reporting; the router's grace-bounded drain counts the loss as
    /// `completions_dropped` instead of hanging on it).
    Inject { stall_wall: f64, drop_batch: bool },
}

#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    pub kind: WorkerKind,
    pub arrival_sim: f64,
    pub deadline_sim: f64,
    pub finish_sim: f64,
    pub service_sim: f64,
    /// Wall seconds the real PJRT execution ran *past* the batch's scaled
    /// service budget (0.0 when it fit). A nonzero overrun means the host
    /// couldn't keep up with the modeled service rate at this time-scale;
    /// the router aggregates these into `ServeReport::exec_overruns`
    /// instead of letting them silently stretch completion timestamps.
    pub overrun_wall: f64,
    /// First element of the model output (proof of real compute).
    pub output0: f32,
}

/// Spawn one warm worker thread; returns its message channel. The worker
/// signals `ready` once its executable is compiled — the router must wait
/// for the whole pool before starting the clock, so XLA compile time never
/// leaks into the modeled dynamics.
pub fn spawn_worker(
    kind: WorkerKind,
    artifacts_dir: String,
    batch: usize,
    params: WorkerParams,
    time_scale: f64,
    ready: mpsc::Sender<()>,
    done: mpsc::Sender<Completion>,
) -> anyhow::Result<mpsc::Sender<WorkerMsg>> {
    let (tx, rx) = mpsc::channel::<WorkerMsg>();
    let artifact = match kind {
        WorkerKind::Fpga => format!("app_fpga_b{batch}"),
        WorkerKind::Cpu => format!("app_cpu_b{batch}"),
    };
    std::thread::Builder::new()
        .name(format!("{}-worker", kind.name()))
        .spawn(move || {
            let rt = match Runtime::load(&artifacts_dir) {
                Ok(rt) => rt,
                Err(e) => {
                    eprintln!("worker init failed: {e:#}");
                    return;
                }
            };
            let exe = match rt.compile(&artifact) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("worker compile failed: {e:#}");
                    return;
                }
            };
            let d_in = exe.arg_specs()[0].shape[1];
            let mut inputs = vec![0.0f32; batch * d_in];
            let mut meta: Vec<Job> = Vec::with_capacity(batch);
            // Pending chaos injection, consumed by the next batch.
            let mut inject: Option<(f64, bool)> = None;
            let _ = ready.send(());

            loop {
                // Parked: wait for activation.
                let (epoch, spin_up) = match rx.recv() {
                    Ok(WorkerMsg::Activate { epoch, spin_up }) => (epoch, spin_up),
                    Ok(WorkerMsg::Park) => continue,
                    Ok(WorkerMsg::Inject { stall_wall, drop_batch }) => {
                        inject = Some((stall_wall, drop_batch));
                        continue;
                    }
                    Ok(WorkerMsg::Job(_)) => {
                        debug_assert!(false, "job sent to parked worker");
                        continue;
                    }
                    _ => return,
                };
                // Reconfiguration / cold-start latency (scaled; 0 when the
                // router activates a pre-warmed worker).
                if spin_up > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(spin_up / time_scale));
                }

                // Active: serve until parked or shut down.
                loop {
                    let first = match rx.recv() {
                        Ok(WorkerMsg::Job(j)) => j,
                        Ok(WorkerMsg::Park) => break,
                        Ok(WorkerMsg::Activate { .. }) => continue,
                        Ok(WorkerMsg::Inject { stall_wall, drop_batch }) => {
                            inject = Some((stall_wall, drop_batch));
                            continue;
                        }
                        _ => return,
                    };
                    meta.clear();
                    meta.push(first);
                    let mut park_after = false;
                    let mut exit_after = false;
                    while meta.len() < batch {
                        match rx.try_recv() {
                            Ok(WorkerMsg::Job(j)) => meta.push(j),
                            Ok(WorkerMsg::Park) => {
                                park_after = true;
                                break;
                            }
                            Ok(WorkerMsg::Activate { .. }) => {}
                            Ok(WorkerMsg::Inject { stall_wall, drop_batch }) => {
                                inject = Some((stall_wall, drop_batch));
                            }
                            Ok(WorkerMsg::Shutdown) => {
                                exit_after = true;
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                    run_batch(
                        kind, &exe, &mut inputs, &meta, batch, d_in, &params, time_scale,
                        epoch, &done, inject.take(),
                    );
                    if exit_after {
                        return;
                    }
                    if park_after {
                        break;
                    }
                }
            }
        })?;
    Ok(tx)
}

#[allow(clippy::too_many_arguments)]
fn run_batch(
    kind: WorkerKind,
    exe: &crate::runtime::Executable,
    inputs: &mut [f32],
    meta: &[Job],
    batch: usize,
    d_in: usize,
    params: &WorkerParams,
    time_scale: f64,
    epoch: Instant,
    done: &mpsc::Sender<Completion>,
    inject: Option<(f64, bool)>,
) {
    inputs.fill(0.0);
    for (slot, job) in meta.iter().enumerate().take(batch) {
        let n = job.input.len().min(d_in);
        inputs[slot * d_in..slot * d_in + n].copy_from_slice(&job.input[..n]);
    }
    let exec_start = Instant::now();
    let out = match exe.run_f32(&[&inputs[..]]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("execution failed: {e:#}");
            return;
        }
    };
    // Injected stall: burns wall time inside the execution window, so it
    // lands in `overrun_wall` like any real slowdown would.
    if let Some((stall_wall, _)) = inject {
        if stall_wall > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(stall_wall));
        }
    }
    // Emulate the Table 6 service time for the batch: the modeled
    // application takes size/speedup per item; the *real* PJRT execution
    // counts toward that budget (deducted from the sleep) so the worker's
    // wall-clock capacity matches the model exactly. If real execution
    // exceeds the scaled budget the time-scale is too aggressive for this
    // host — the overrun is reported on every completion in the batch so
    // the router can count it instead of it silently stretching finish
    // timestamps.
    let batch_service: f64 = meta.iter().map(|j| j.size / params.speedup).sum();
    let budget = Duration::from_secs_f64(batch_service / time_scale);
    let spent = exec_start.elapsed();
    let overrun_wall = spent.saturating_sub(budget).as_secs_f64();
    if budget > spent {
        std::thread::sleep(budget - spent);
    }
    let finish = epoch.elapsed().as_secs_f64() * time_scale;
    if let Some((_, true)) = inject {
        // Drop injection: the batch really executed (and the stall was
        // paid) but its records are swallowed — the router's drain counts
        // the gap as `completions_dropped`.
        return;
    }
    for (slot, job) in meta.iter().enumerate() {
        let _ = done.send(Completion {
            id: job.id,
            kind,
            arrival_sim: job.arrival_sim,
            deadline_sim: job.deadline_sim,
            finish_sim: finish,
            service_sim: job.size / params.speedup,
            overrun_wall,
            output0: out[slot * 128],
        });
    }
}

/// Grace-bounded completion drain for router shutdown. Collects records
/// until every sender hangs up (clean drain, `timed_out == false`) or
/// `grace` wall time elapses (`timed_out == true`) — whichever comes
/// first, with a final non-blocking sweep either way. This is what makes
/// a permanently wedged worker thread (stalled inside its executable,
/// never dropping its sender) unable to hang `run_serve_*` shutdown: the
/// old unbounded `recv` loop would block on that live sender forever.
pub fn drain_completions(
    rx: &mpsc::Receiver<Completion>,
    grace: Duration,
) -> (Vec<Completion>, bool) {
    let deadline = Instant::now() + grace;
    let mut out = Vec::new();
    loop {
        let now = Instant::now();
        if now >= deadline {
            while let Ok(c) = rx.try_recv() {
                out.push(c);
            }
            return (out, true);
        }
        match rx.recv_timeout(deadline - now) {
            Ok(c) => out.push(c),
            Err(mpsc::RecvTimeoutError::Disconnected) => return (out, false),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                while let Ok(c) = rx.try_recv() {
                    out.push(c);
                }
                return (out, true);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(id: u64) -> Completion {
        Completion {
            id,
            kind: WorkerKind::Cpu,
            arrival_sim: 0.0,
            deadline_sim: 1.0,
            finish_sim: 0.5,
            service_sim: 0.5,
            overrun_wall: 0.0,
            output0: 0.0,
        }
    }

    #[test]
    fn clean_drain_returns_everything_without_timing_out() {
        let (tx, rx) = mpsc::channel::<Completion>();
        tx.send(completion(1)).unwrap();
        tx.send(completion(2)).unwrap();
        drop(tx);
        let (got, timed_out) = drain_completions(&rx, Duration::from_secs(30));
        assert_eq!(got.len(), 2);
        assert!(!timed_out, "all senders hung up — no grace should be spent");
    }

    #[test]
    fn stalled_sender_cannot_hang_the_drain() {
        // A wedged worker thread keeps its completion sender alive forever
        // (stalled mid-execution). The drain must return at the grace
        // deadline with whatever arrived — not block on the live sender,
        // which is exactly what the pre-grace unbounded recv loop did.
        let (tx, rx) = mpsc::channel::<Completion>();
        tx.send(completion(1)).unwrap();
        let hostage = tx.clone();
        std::thread::spawn(move || {
            // Holds the sender hostage well past the test's grace window;
            // the detached thread dies with the test process.
            std::thread::sleep(Duration::from_secs(60));
            drop(hostage);
        });
        drop(tx);
        let start = Instant::now();
        let (got, timed_out) = drain_completions(&rx, Duration::from_millis(200));
        assert_eq!(got.len(), 1, "records sent before the wedge must drain");
        assert!(timed_out, "a live hostage sender must trip the grace");
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "drain must return at the grace bound, not wait for the wedged worker"
        );
    }
}
