//! Experiment harness: one runner per paper table/figure (see DESIGN.md
//! §4 for the index). `spork experiment <id>` regenerates the table, both
//! to stdout and under `results/` as txt/csv/md.

pub mod ablation;
pub mod benchserve;
pub mod benchsim;
pub mod common;
pub mod offline;
pub mod production_exp;
pub mod scenario;
pub mod sensitivity;
pub mod sweep;

pub use benchserve::{
    cmd_bench_serve, run_bench_serve, run_bench_serve_chaos, BenchServePoint, BenchServeReport,
    BenchServeSpec, ChaosBenchReport,
};
pub use benchsim::{
    cmd_bench_sim, run_bench_sim, run_bench_sim_scenario, run_fit_bench, run_par_apps_bench,
    run_pool_scaling, BenchSimReport, FitBenchReport, FitSearchReport, ParAppsBenchReport,
    ParAppsPoint, PoolScalePoint, ScenarioBenchReport,
};
pub use common::{Cell, ExpCtx};
pub use sweep::{SweepCell, SweepGrid, WorkloadSpec};

use crate::cli::Args;
use crate::report;
use crate::util::table::Table;
use std::path::PathBuf;
use std::time::Instant;

type Runner = fn(&ExpCtx) -> Vec<Table>;

/// The experiment registry: id → (runner, description).
pub fn registry() -> Vec<(&'static str, Runner, &'static str)> {
    vec![
        ("fig2", offline::fig2 as Runner, "optimal scheduling vs burstiness (energy/cost)"),
        ("fig3", offline::fig3, "pareto-optimal energy/cost frontier"),
        ("table8", production_exp::table8, "scheduler roster on production workloads"),
        ("table9", production_exp::table9, "dispatch policy ablation"),
        ("fig4", sensitivity::fig4, "Spork vs MArk-ideal @ 60s spin-up"),
        ("fig5", sensitivity::fig5, "burstiness x spin-up sensitivity"),
        ("fig6", sensitivity::fig6, "speedup x busy-power sensitivity"),
        ("fig7", sensitivity::fig7, "request-size sensitivity"),
        ("ablation", ablation::ablation, "design-choice ablations (predictor, idle timeout, deadline-aware)"),
        ("scenario", scenario::scenario, "schedulers under spot preemption and worker failure"),
    ]
}

/// Run one experiment (or "all"); prints and writes tables.
pub fn run(id: &str, ctx: &ExpCtx) -> Result<Vec<Table>, String> {
    let registry = registry();
    let selected: Vec<_> = if id == "all" {
        registry
    } else {
        registry
            .into_iter()
            .filter(|(name, _, _)| *name == id)
            .collect()
    };
    if selected.is_empty() {
        return Err(format!(
            "unknown experiment '{id}' (try: fig2 fig3 fig4 fig5 fig6 fig7 table8 table9 ablation scenario all)"
        ));
    }
    let mut all_tables = Vec::new();
    for (name, runner, desc) in selected {
        eprintln!("== running {name}: {desc} ==");
        let t0 = Instant::now();
        let tables = runner(ctx);
        for (i, table) in tables.iter().enumerate() {
            print!("{}", table.render());
            println!();
            let stem = if tables.len() == 1 {
                name.to_string()
            } else {
                format!("{name}_{i}")
            };
            report::write_table(table, &ctx.out_dir, &stem)
                .map_err(|e| format!("writing results: {e}"))?;
        }
        eprintln!("== {name} done in {:.1}s ==", t0.elapsed().as_secs_f64());
        all_tables.extend(tables);
    }
    Ok(all_tables)
}

/// `spork experiment` CLI entrypoint.
pub fn cmd_experiment(args: &Args) -> Result<(), String> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let jobs = args.usize_or("jobs", 0)?;
    // `--jobs` is one process-wide budget (DESIGN.md §14): seed the
    // global executor before any grid or per-app fan-out runs, so every
    // nesting level draws from the same permit pool.
    crate::util::executor::Executor::configure(jobs);
    let ctx = ExpCtx {
        out_dir: PathBuf::from(args.str_or("out", "results")),
        seeds: args.u64_or("seeds", if id.starts_with("table") { 1 } else { 3 })?,
        scale: args.f64_or("scale", 1.0)?,
        full: args.has_flag("full"),
        jobs,
    };
    run(id, &ctx).map(|_| ())
}
