//! Ablations of Spork's design choices (beyond the paper's own Table 9
//! dispatch ablation): the Alg-2 predictor vs naive last-value prediction
//! vs the oracle, the idle-timeout reclamation window, and the §4.5
//! deadline-aware allocation extension.

use super::common::{Cell, ExpCtx};
use super::sweep::parallel_map;
use crate::config::{PlatformConfig, SimConfig};
use crate::policy::Policy;
use crate::sched::{self, Objective, Oracle};
use crate::sim;
use crate::trace::synthetic_app;
use crate::util::rng::Rng;
use crate::util::table::{pct, ratio, Table};

/// Run a custom-built Spork variant over the ablation workload, one
/// independent RNG stream per seed, replicates merged in seed order.
fn run_spork(
    ctx: &ExpCtx,
    cfg: &SimConfig,
    b: f64,
    make: impl Fn(&SimConfig, &crate::trace::AppTrace) -> Box<dyn Policy> + Sync,
) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let seeds: Vec<u64> = (0..ctx.seeds).collect();
    let runs = parallel_map(&seeds, ctx.effective_jobs(), |_, &s| {
        let mut rng = Rng::for_stream(900, s);
        let trace = synthetic_app(
            "abl",
            &mut rng,
            b,
            ctx.synthetic_duration(),
            ctx.synthetic_rate(),
            0.010,
        );
        let mut sched = make(cfg, &trace);
        let r = sim::run(&trace, cfg.clone(), &defaults, sched.as_mut());
        Cell::from_run(&r.metrics, &r.ideal)
    });
    let mut cell = Cell::default();
    for run in &runs {
        cell.merge(run);
    }
    cell.finish()
}

/// Ablation tables: predictor, idle timeout, deadline-aware.
pub fn ablation(ctx: &ExpCtx) -> Vec<Table> {
    let mut tables = Vec::new();

    // 1. Predictor ablation.
    let mut t = Table::new(
        "Ablation A: Spork's Alg-2 predictor vs last-value vs oracle (SporkE)",
        &["b", "Predictor", "Energy Eff.", "Rel. Cost", "FPGA spin-ups"],
    );
    for &b in &[0.55, 0.65, 0.75] {
        let cfg = SimConfig::paper_default();
        let rows: Vec<(&str, Cell)> = vec![
            (
                "last-value",
                run_spork(ctx, &cfg, b, |c, _| {
                    Box::new(
                        sched::spork::Spork::new(c, Objective::energy())
                            .with_last_value_predictor(),
                    )
                }),
            ),
            (
                "Alg 2 (histogram)",
                run_spork(ctx, &cfg, b, |c, _| {
                    Box::new(sched::spork::Spork::new(c, Objective::energy()))
                }),
            ),
            (
                "oracle",
                run_spork(ctx, &cfg, b, |c, trace| {
                    let o = Oracle::from_trace(trace, c, Objective::energy());
                    Box::new(sched::spork::Spork::ideal(c, Objective::energy(), o))
                }),
            ),
        ];
        for (name, cell) in rows {
            t.row(vec![
                format!("{b}"),
                name.into(),
                pct(cell.energy_eff),
                ratio(cell.rel_cost),
                format!("{:.0}", cell.fpga_spinups),
            ]);
        }
    }
    tables.push(t);

    // 2. Idle-timeout window (paper: one allocation duration).
    let mut t = Table::new(
        "Ablation B: idle-timeout reclamation window (SporkE, b=0.65)",
        &["timeout / T_s", "Energy Eff.", "Rel. Cost", "FPGA spin-ups"],
    );
    for &mult in &[0.5, 1.0, 2.0, 4.0] {
        let mut cfg = SimConfig::paper_default();
        cfg.fpga_idle_timeout = mult * cfg.interval;
        let cell = run_spork(ctx, &cfg, 0.65, |c, _| {
            Box::new(sched::spork::Spork::new(c, Objective::energy()))
        });
        t.row(vec![
            format!("{mult}x"),
            pct(cell.energy_eff),
            ratio(cell.rel_cost),
            format!("{:.0}", cell.fpga_spinups),
        ]);
    }
    tables.push(t);

    // 3. §4.5 deadline-aware allocation (future-work extension).
    let mut t = Table::new(
        "Ablation C: deadline-aware allocation extension (§4.5, SporkE)",
        &["b", "Variant", "Energy Eff.", "Rel. Cost", "Miss %"],
    );
    for &b in &[0.6, 0.7] {
        for (name, aware) in [("paper (off)", false), ("deadline-aware", true)] {
            let mut cfg = SimConfig::paper_default();
            cfg.deadline_aware = aware;
            let cell = run_spork(ctx, &cfg, b, |c, _| {
                Box::new(sched::spork::Spork::new(c, Objective::energy()))
            });
            t.row(vec![
                format!("{b}"),
                name.into(),
                pct(cell.energy_eff),
                ratio(cell.rel_cost),
                pct(cell.miss_frac),
            ]);
        }
    }
    tables.push(t);
    tables
}
