//! Deterministic parallel sweep engine for the experiment harness.
//!
//! Every figure/table in the paper is a grid of (scheduler, workload,
//! seed) cells, and each cell is an independent simulation — the classic
//! embarrassingly-parallel parameter sweep. [`SweepGrid`] makes the grid
//! *declarative*: experiments push cells, `run()` executes them across
//! `std::thread::scope` workers, and the result vector comes back in push
//! order.
//!
//! Determinism contract (tested in `rust/tests/determinism.rs`): results
//! are **bit-identical for every `--jobs` value**, because
//!
//! 1. each (cell, seed) replicate draws from its own RNG stream derived
//!    as a pure function of `(seed_base, seed)` via [`Rng::for_stream`]
//!    — no shared generator is consumed in scheduling order;
//! 2. workers return `(index, Cell)` pairs and the engine re-assembles
//!    them by index, so floating-point merge order never depends on
//!    which thread finished first.
//!
//! The lower-level [`parallel_map`] is shared by the experiments whose
//! cells do not fit the synthetic-workload shape (production tables,
//! offline fig2/fig3 solves, ablations).

use super::common::{Cell, ExpCtx};
use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::sched;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A synthetic (b-model) workload point of a sweep grid.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub burstiness: f64,
    /// Mean request rate (req/s).
    pub rate: f64,
    /// Request size (CPU-seconds).
    pub size: f64,
    /// Trace duration (seconds).
    pub duration: f64,
}

/// One declarative grid cell: a scheduler on a platform config and
/// workload, replicated over the grid's seed count.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub scheduler: SchedulerKind,
    pub cfg: SimConfig,
    pub workload: WorkloadSpec,
    /// Root of this cell's RNG streams; replicate `s` uses
    /// `Rng::for_stream(seed_base, s)`.
    pub seed_base: u64,
}

/// A declarative grid of sweep cells with an execution policy.
pub struct SweepGrid {
    cells: Vec<SweepCell>,
    seeds: u64,
    jobs: usize,
}

impl SweepGrid {
    /// Grid with explicit seed replication and worker count (`jobs == 0`
    /// means one worker per available core).
    pub fn with(seeds: u64, jobs: usize) -> Self {
        Self {
            cells: Vec::new(),
            seeds: seeds.max(1),
            jobs,
        }
    }

    /// Grid driven by an experiment context (its seed count and `--jobs`).
    pub fn from_ctx(ctx: &ExpCtx) -> Self {
        Self::with(ctx.seeds, ctx.jobs)
    }

    /// Add a cell; returns its index in `run()`'s result vector.
    pub fn push(&mut self, cell: SweepCell) -> usize {
        self.cells.push(cell);
        self.cells.len() - 1
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Execute every (cell, seed) replicate, merge replicates per cell,
    /// and return one seed-averaged [`Cell`] per pushed cell, in push
    /// order. Bit-identical for any worker count.
    pub fn run(&self) -> Vec<Cell> {
        let defaults = PlatformConfig::paper_default();
        let seeds = self.seeds;
        let units: Vec<(usize, u64)> = (0..self.cells.len())
            .flat_map(|c| (0..seeds).map(move |s| (c, s)))
            .collect();
        let runs = parallel_map(&units, self.jobs, |_, &(c, s)| {
            let cell = &self.cells[c];
            let w = &cell.workload;
            // Single-pass kinds stream the workload straight into the
            // driver: the b-model synthesis is lazy (sequence-identical
            // to the materialized `synthetic_app`, pinned by
            // tests/source_parity.rs), so a cell's memory is bounded by
            // pool + events, not trace length. Multi-pass kinds (oracle
            // construction / the §5.1 fitting searches replay the
            // workload up to ~11 times) synthesize once and re-run the
            // materialized trace instead — sweep cells are bounded, so
            // trading that memory for not re-synthesizing every pass is
            // the right call here; genuinely huge streams go through
            // `run_scheduler_source` with a re-creatable factory.
            let source = || {
                crate::trace::synthetic_source(
                    "exp",
                    Rng::for_stream(cell.seed_base, s),
                    w.burstiness,
                    w.duration,
                    w.rate,
                    w.size,
                    60.0,
                )
            };
            let r = match &cell.scheduler {
                SchedulerKind::CpuDynamic | SchedulerKind::Spork { ideal: false, .. } => {
                    sched::run_scheduler_source(&cell.scheduler, &cell.cfg, &defaults, &|| {
                        Box::new(source())
                    })
                }
                _ => {
                    let trace = crate::trace::AppTrace::from_source(&mut source());
                    sched::run_scheduler(&cell.scheduler, &trace, &cell.cfg, &defaults)
                }
            };
            Cell::from_run(&r.metrics, &r.ideal)
        });
        // Merge replicates in unit order (units are sorted by (cell,
        // seed)), so float accumulation order is fixed.
        let mut merged = vec![Cell::default(); self.cells.len()];
        for (&(c, _s), run) in units.iter().zip(&runs) {
            merged[c].merge(run);
        }
        merged.into_iter().map(Cell::finish).collect()
    }
}

/// Resolve a `--jobs` value: `0` means auto (one worker per core).
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        jobs
    }
}

/// Order-preserving parallel map: applies `f` to every item across up to
/// `jobs` scoped worker threads (work-stealing over an atomic cursor) and
/// returns results in item order. `f(i, item)` must depend only on its
/// arguments for the output to be deterministic — *scheduling* order is
/// not deterministic, result *placement* is.
pub fn parallel_map<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let jobs = effective_jobs(jobs).min(items.len().max(1));
    if jobs <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(jobs);
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs)
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for w in workers {
            parts.push(w.join().expect("sweep worker panicked"));
        }
    });
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in parts.into_iter().flatten() {
        debug_assert!(slots[i].is_none(), "duplicate sweep result for {i}");
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .map(|r| r.expect("missing sweep result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order_and_coverage() {
        let items: Vec<u64> = (0..257).collect();
        for jobs in [1, 2, 7] {
            let out = parallel_map(&items, jobs, |i, &x| {
                assert_eq!(i as u64, x);
                x * 3 + 1
            });
            assert_eq!(out.len(), items.len());
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as u64 * 3 + 1, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let out: Vec<u32> = parallel_map(&[], 4, |_, x: &u32| *x);
        assert!(out.is_empty());
        let out = parallel_map(&[9u32], 4, |_, x| x + 1);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn effective_jobs_resolves_auto() {
        assert!(effective_jobs(0) >= 1);
        assert_eq!(effective_jobs(3), 3);
    }

    #[test]
    fn grid_runs_cells_in_push_order() {
        use crate::config::SimConfig;
        let mut grid = SweepGrid::with(1, 2);
        let cfg = SimConfig::paper_default();
        for &b in &[0.5, 0.7] {
            grid.push(SweepCell {
                scheduler: SchedulerKind::CpuDynamic,
                cfg: cfg.clone(),
                workload: WorkloadSpec {
                    burstiness: b,
                    rate: 50.0,
                    size: 0.010,
                    duration: 60.0,
                },
                seed_base: 5,
            });
        }
        let cells = grid.run();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.runs, 1);
            assert!(c.energy_eff > 0.0);
        }
    }
}
