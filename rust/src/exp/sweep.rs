//! Deterministic parallel sweep engine for the experiment harness.
//!
//! Every figure/table in the paper is a grid of (scheduler, workload,
//! seed) cells, and each cell is an independent simulation — the classic
//! embarrassingly-parallel parameter sweep. [`SweepGrid`] makes the grid
//! *declarative*: experiments push cells, `run()` executes them across
//! `std::thread::scope` workers, and the result vector comes back in push
//! order.
//!
//! Determinism contract (tested in `rust/tests/determinism.rs`): results
//! are **bit-identical for every `--jobs` value**, because
//!
//! 1. each (cell, seed) replicate draws from its own RNG stream derived
//!    as a pure function of `(seed_base, seed)` via [`Rng::for_stream`]
//!    — no shared generator is consumed in scheduling order;
//! 2. workers return `(index, Cell)` pairs and the engine re-assembles
//!    them by index, so floating-point merge order never depends on
//!    which thread finished first.
//!
//! The lower-level [`parallel_map`] is shared by the experiments whose
//! cells do not fit the synthetic-workload shape (production tables,
//! offline fig2/fig3 solves, ablations). Since the bounded-executor
//! refactor it is a thin veneer over [`Executor::global`] (DESIGN.md
//! §14): the grid draws its workers from the same process-wide permit
//! pool as the per-app and lockstep-fitting fan-outs nested inside its
//! cells, so `--jobs` bounds *total* live threads, not threads per
//! nesting level.

use super::common::{Cell, ExpCtx};
use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::scenario::ScenarioConfig;
use crate::sched::{self, WorkloadProfile};
use crate::trace::AppTrace;
use crate::util::executor::{panic_message, Executor};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

pub use crate::util::executor::effective_jobs;

/// A synthetic (b-model) workload point of a sweep grid.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub burstiness: f64,
    /// Mean request rate (req/s).
    pub rate: f64,
    /// Request size (CPU-seconds).
    pub size: f64,
    /// Trace duration (seconds).
    pub duration: f64,
}

/// One declarative grid cell: a scheduler on a platform config and
/// workload, replicated over the grid's seed count.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub scheduler: SchedulerKind,
    pub cfg: SimConfig,
    pub workload: WorkloadSpec,
    /// Root of this cell's RNG streams; replicate `s` uses
    /// `Rng::for_stream(seed_base, s)`.
    pub seed_base: u64,
    /// Fault scenario the cell's evaluation runs replay under (`None` =
    /// the plain fault-free path). Fitting and oracle construction stay
    /// fault-free either way (§5.1); the scenario only shapes the final
    /// evaluation run, with its fault plan derived per replicate from
    /// `(seed_base, seed)` — workload-profile sharing is unaffected
    /// because the synthesized arrivals are scenario-independent.
    pub scenario: Option<ScenarioConfig>,
}

/// A declarative grid of sweep cells with an execution policy.
pub struct SweepGrid {
    cells: Vec<SweepCell>,
    seeds: u64,
    jobs: usize,
}

impl SweepGrid {
    /// Grid with explicit seed replication and worker count (`jobs == 0`
    /// means one worker per available core).
    pub fn with(seeds: u64, jobs: usize) -> Self {
        Self {
            cells: Vec::new(),
            seeds: seeds.max(1),
            jobs,
        }
    }

    /// Grid driven by an experiment context (its seed count and `--jobs`).
    pub fn from_ctx(ctx: &ExpCtx) -> Self {
        Self::with(ctx.seeds, ctx.jobs)
    }

    /// Add a cell; returns its index in `run()`'s result vector.
    pub fn push(&mut self, cell: SweepCell) -> usize {
        self.cells.push(cell);
        self.cells.len() - 1
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Execute every (cell, seed) replicate, merge replicates per cell,
    /// and return one seed-averaged [`Cell`] per pushed cell, in push
    /// order. Bit-identical for any worker count.
    ///
    /// Workload synthesis is shared: every (cell, seed) unit whose
    /// workload identity — `(seed_base, seed, workload spec, scheduling
    /// interval)` — matches runs against one cached [`WorkloadProfile`]
    /// (Arc-shared trace + per-interval work bins), so a roster of N
    /// scheduler kinds on one workload pays b-model + Poisson synthesis
    /// once instead of N times, and the oracle-assisted kinds derive
    /// their needed-counts from the cached bins instead of re-streaming
    /// the arrivals. Only keys that are both shared (>1 consuming unit)
    /// AND read by at least one profile-consuming kind are materialized
    /// up front and held for the grid's lifetime; every other unit keeps
    /// the pre-cache cost model — single-pass kinds stream in constant
    /// memory, multi-pass kinds build a transient local profile — so
    /// grid memory never exceeds the old bound of ~`jobs` live traces
    /// plus the genuinely shared ones. Determinism is unchanged
    /// because a profile is a pure function of its key and results are
    /// still placed by unit index (bit-parity with per-cell
    /// recomputation, across both the shared and unshared branches, is
    /// pinned by `rust/tests/fit_parity.rs` and the
    /// `shared_profiles_do_not_couple_cells` test below). Platform
    /// parameters are *not* part of the key: bins are pre-breakeven
    /// demand, so sensitivity sweeps that vary speedup/power/spin-up
    /// share profiles across configs whenever the scheduling interval
    /// agrees.
    pub fn run(&self) -> Vec<Cell> {
        let seeds = self.seeds;
        let units: Vec<(usize, u64)> = (0..self.cells.len())
            .flat_map(|c| (0..seeds).map(move |s| (c, s)))
            .collect();

        // Resolve each unit to its workload-profile key, first occurrence
        // first — the profile list order is a pure function of the grid,
        // independent of worker count — and count consumers per key.
        let mut key_index: HashMap<ProfileKey, usize> = HashMap::new();
        let mut key_specs: Vec<(u64, u64, WorkloadSpec, f64)> = Vec::new();
        let mut key_uses: Vec<usize> = Vec::new();
        let mut key_needs_profile: Vec<bool> = Vec::new();
        let mut unit_key: Vec<usize> = Vec::with_capacity(units.len());
        for &(c, s) in &units {
            let cell = &self.cells[c];
            let key = ProfileKey::of(cell, s);
            let idx = *key_index.entry(key).or_insert_with(|| {
                key_specs.push((cell.seed_base, s, cell.workload.clone(), cell.cfg.interval));
                key_uses.push(0);
                key_needs_profile.push(false);
                key_specs.len() - 1
            });
            key_uses[idx] += 1;
            key_needs_profile[idx] |= needs_profile(&cell.scheduler);
            unit_key.push(idx);
        }

        // Synthesize each genuinely shared workload exactly once (in
        // parallel — profiles are pure functions of their key). A key is
        // worth pinning for the grid's lifetime only when it is shared
        // AND some consumer actually reads the materialized trace/bins
        // (a multi-pass or oracle-assisted kind); keys consumed solely
        // by streaming kinds would hold O(arrivals) memory nobody needs.
        let shared: Vec<Option<WorkloadProfile>> =
            parallel_map(&key_specs, self.jobs, |i, spec| {
                (key_uses[i] > 1 && key_needs_profile[i]).then(|| synth_profile(spec))
            });

        let runs = parallel_map(&units, self.jobs, |u, &(c, s)| {
            let cell = &self.cells[c];
            // Attribute a panicking unit to its grid cell: the executor
            // re-raises with the flat item index, this layer adds the
            // cell key (scheduler, seed_base, seed) a human can act on.
            match catch_unwind(AssertUnwindSafe(|| run_unit(cell, s, &shared[unit_key[u]]))) {
                Ok(r) => r,
                Err(payload) => panic!(
                    "sweep cell {} (seed_base {}, seed {}): {}",
                    cell.scheduler.name(),
                    cell.seed_base,
                    s,
                    panic_message(payload.as_ref())
                ),
            }
        });
        // Merge replicates in unit order (units are sorted by (cell,
        // seed)), so float accumulation order is fixed.
        let mut merged = vec![Cell::default(); self.cells.len()];
        for (&(c, _s), run) in units.iter().zip(&runs) {
            merged[c].merge(run);
        }
        merged.into_iter().map(Cell::finish).collect()
    }
}

/// Evaluate one (cell, seed) replicate — the body of the grid's unit
/// fan-out, hoisted out so the panic-attribution wrapper above stays
/// readable.
fn run_unit(cell: &SweepCell, s: u64, shared: &Option<WorkloadProfile>) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let w = &cell.workload;
    let synth = || {
        crate::trace::synthetic_source(
            "exp",
            Rng::for_stream(cell.seed_base, s),
            w.burstiness,
            w.duration,
            w.rate,
            w.size,
            60.0,
        )
    };
    let r = match (&cell.scenario, shared) {
        // Scenario cell: fit/build fault-free, then replay the
        // evaluation run under the cell's fault plan (derived
        // per replicate from `(seed_base, s)`). The profile, when
        // shared, still supplies the arrivals.
        (Some(scen), Some(profile)) => sched::run_scheduler_scenario(
            &cell.scheduler,
            &cell.cfg,
            &defaults,
            &|| Box::new(profile.source()),
            scen,
            cell.seed_base,
            s,
        ),
        (Some(scen), None) => sched::run_scheduler_scenario(
            &cell.scheduler,
            &cell.cfg,
            &defaults,
            &|| Box::new(synth()),
            scen,
            cell.seed_base,
            s,
        ),
        (None, Some(profile)) => {
            sched::run_scheduler_profile(&cell.scheduler, profile, &cell.cfg, &defaults)
        }
        // Unshared unit: the old per-unit cost model. Single-pass
        // kinds stream the lazy synthesis (constant memory);
        // multi-pass kinds build a transient profile dropped at
        // the end of the unit.
        (None, None) => match &cell.scheduler {
            SchedulerKind::CpuDynamic
            | SchedulerKind::GreedySpot
            | SchedulerKind::OndemandFallback
            | SchedulerKind::SporkFallback
            | SchedulerKind::Spork { ideal: false, .. } => sched::run_scheduler_source(
                &cell.scheduler,
                &cell.cfg,
                &defaults,
                &|| Box::new(synth()),
            ),
            _ => {
                let trace = AppTrace::from_source(&mut synth());
                let profile = WorkloadProfile::from_trace(trace, cell.cfg.interval);
                sched::run_scheduler_profile(&cell.scheduler, &profile, &cell.cfg, &defaults)
            }
        },
    };
    Cell::from_run(&r.metrics, &r.ideal)
}

/// Whether a kind's run path consumes a [`WorkloadProfile`] — the
/// multi-pass fitted baselines and the oracle-assisted kinds. The
/// remaining kinds make exactly one streaming pass and never read the
/// materialized trace or its bins.
fn needs_profile(kind: &SchedulerKind) -> bool {
    !matches!(
        kind,
        SchedulerKind::CpuDynamic
            | SchedulerKind::GreedySpot
            | SchedulerKind::OndemandFallback
            | SchedulerKind::SporkFallback
            | SchedulerKind::Spork { ideal: false, .. }
    )
}

/// Materialize one workload profile from its key spec (a pure function
/// of the spec — the determinism contract's cornerstone).
fn synth_profile(
    (seed_base, seed, w, interval): &(u64, u64, WorkloadSpec, f64),
) -> WorkloadProfile {
    let trace = AppTrace::from_source(&mut crate::trace::synthetic_source(
        "exp",
        Rng::for_stream(*seed_base, *seed),
        w.burstiness,
        w.duration,
        w.rate,
        w.size,
        60.0,
    ));
    WorkloadProfile::new(Arc::new(trace), *interval)
}

/// Workload identity of one (cell, seed) unit: everything the
/// synthesized trace and its interval bins are a function of. Floats are
/// keyed by their bit patterns — profile sharing requires *exact*
/// parameter equality, anything less would let two almost-equal cells
/// silently share a trace.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ProfileKey {
    seed_base: u64,
    seed: u64,
    burstiness: u64,
    rate: u64,
    size: u64,
    duration: u64,
    interval: u64,
}

impl ProfileKey {
    fn of(cell: &SweepCell, seed: u64) -> Self {
        Self {
            seed_base: cell.seed_base,
            seed,
            burstiness: cell.workload.burstiness.to_bits(),
            rate: cell.workload.rate.to_bits(),
            size: cell.workload.size.to_bits(),
            duration: cell.workload.duration.to_bits(),
            interval: cell.cfg.interval.to_bits(),
        }
    }
}

/// Order-preserving parallel map over the **global** executor: applies
/// `f` to every item across the calling thread plus up to `jobs - 1`
/// permit-backed workers and returns results in item order (`jobs == 0`
/// means "whatever the budget allows"). `f(i, item)` must depend only
/// on its arguments for the output to be deterministic — *scheduling*
/// order is not deterministic, result *placement* is. A worker panic is
/// re-raised with the failing item index. Kept as a named entry point
/// for the experiment callers; the mechanics live in
/// [`crate::util::executor`].
pub fn parallel_map<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    Executor::global().map(items, jobs, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order_and_coverage() {
        let items: Vec<u64> = (0..257).collect();
        for jobs in [1, 2, 7] {
            let out = parallel_map(&items, jobs, |i, &x| {
                assert_eq!(i as u64, x);
                x * 3 + 1
            });
            assert_eq!(out.len(), items.len());
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as u64 * 3 + 1, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let out: Vec<u32> = parallel_map(&[], 4, |_, x: &u32| *x);
        assert!(out.is_empty());
        let out = parallel_map(&[9u32], 4, |_, x| x + 1);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn effective_jobs_resolves_auto() {
        assert!(effective_jobs(0) >= 1);
        assert_eq!(effective_jobs(3), 3);
    }

    #[test]
    fn shared_profiles_do_not_couple_cells() {
        // Kinds sharing one workload profile must produce exactly what
        // each produces in a grid of its own (the cache shares synthesis,
        // never state).
        use crate::config::SimConfig;
        let w = WorkloadSpec {
            burstiness: 0.65,
            rate: 80.0,
            size: 0.010,
            duration: 120.0,
        };
        let kinds = [SchedulerKind::spork_e(), SchedulerKind::MarkIdeal];
        let mut grid = SweepGrid::with(2, 2);
        for kind in &kinds {
            grid.push(SweepCell {
                scheduler: kind.clone(),
                cfg: SimConfig::paper_default(),
                workload: w.clone(),
                seed_base: 9,
                scenario: None,
            });
        }
        let shared = grid.run();
        for (kind, cell) in kinds.iter().zip(&shared) {
            let mut solo = SweepGrid::with(2, 1);
            solo.push(SweepCell {
                scheduler: kind.clone(),
                cfg: SimConfig::paper_default(),
                workload: w.clone(),
                seed_base: 9,
                scenario: None,
            });
            assert_eq!(&solo.run()[0], cell, "{} diverged", kind.name());
        }
    }

    #[test]
    fn streaming_only_shared_keys_match_solo_grids() {
        // Two single-pass kinds sharing one workload key: the cache
        // skips materialization (nobody reads the profile), both units
        // stream — output must still equal each kind's solo grid.
        use crate::config::SimConfig;
        let w = WorkloadSpec {
            burstiness: 0.6,
            rate: 60.0,
            size: 0.010,
            duration: 90.0,
        };
        let kinds = [SchedulerKind::spork_e(), SchedulerKind::spork_c()];
        let mut grid = SweepGrid::with(1, 2);
        for kind in &kinds {
            grid.push(SweepCell {
                scheduler: kind.clone(),
                cfg: SimConfig::paper_default(),
                workload: w.clone(),
                seed_base: 13,
                scenario: None,
            });
        }
        let shared = grid.run();
        for (kind, cell) in kinds.iter().zip(&shared) {
            let mut solo = SweepGrid::with(1, 1);
            solo.push(SweepCell {
                scheduler: kind.clone(),
                cfg: SimConfig::paper_default(),
                workload: w.clone(),
                seed_base: 13,
                scenario: None,
            });
            assert_eq!(&solo.run()[0], cell, "{} diverged", kind.name());
        }
    }

    #[test]
    fn grid_runs_cells_in_push_order() {
        use crate::config::SimConfig;
        let mut grid = SweepGrid::with(1, 2);
        let cfg = SimConfig::paper_default();
        for &b in &[0.5, 0.7] {
            grid.push(SweepCell {
                scheduler: SchedulerKind::CpuDynamic,
                cfg: cfg.clone(),
                workload: WorkloadSpec {
                    burstiness: b,
                    rate: 50.0,
                    size: 0.010,
                    duration: 60.0,
                },
                seed_base: 5,
                scenario: None,
            });
        }
        let cells = grid.run();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.runs, 1);
            assert!(c.energy_eff > 0.0);
        }
    }
}
