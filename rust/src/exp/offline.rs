//! Fig 2 and Fig 3: the §3 idealized analysis on the fluid model.
//!
//! Setting (§3.2): hour-long per-second b-model rate traces, 10k req/s
//! average, 10 ms constant requests, paper-default workers, results
//! normalized to the idealized FPGA-only platform and averaged over ten
//! trace runs.

use super::common::ExpCtx;
use crate::config::PlatformConfig;
use crate::opt::{pareto, ranksolve, FluidInstance, PlatformMode};
use crate::sched::Objective;
use crate::trace::{bmodel, RateTrace};
use crate::util::rng::Rng;
use crate::util::table::{pct, ratio, sig3, Table};

const BURSTS: &[f64] = &[0.5, 0.55, 0.6, 0.65, 0.7, 0.75];
/// §3 granularity: per-second intervals; the 10 s FPGA spin-up becomes a
/// 10-interval persistence horizon (Table 3's last constraint).
const S_INTERVALS: usize = 10;

fn instance(ctx: &ExpCtx, b: f64, seed: u64) -> FluidInstance {
    let platform = PlatformConfig::paper_default();
    let duration = if ctx.full { 3600 } else { 1800 };
    let rate = 10_000.0;
    let mut rng = Rng::new(seed);
    let rates = RateTrace::new(1.0, bmodel::bmodel_rates(&mut rng, b, duration, rate));
    // dt = 1 s (NOT the spin-up): §3 evaluates at rate granularity.
    FluidInstance::from_rates(&rates, 0.010, 1.0, platform)
}

/// Fig 2: energy-optimal (a) and cost-optimal (b) scheduling of CPU-only,
/// FPGA-only, and hybrid platforms vs burstiness.
pub fn fig2(ctx: &ExpCtx) -> Vec<Table> {
    let mut tables = Vec::new();
    for (tag, obj) in [("2a energy-optimal", Objective::energy()), ("2b cost-optimal", Objective::cost())] {
        let mut t = Table::new(
            &format!("Fig {tag}: optimal scheduling vs burstiness (normalized to idealized FPGA-only)"),
            &[
                "b",
                "CPU-only eff", "CPU-only cost",
                "FPGA-only eff", "FPGA-only cost",
                "Hybrid eff", "Hybrid cost",
            ],
        );
        for &b in BURSTS {
            let mut acc = [[0.0f64; 2]; 3];
            for s in 0..ctx.seeds {
                let inst = instance(ctx, b, 1000 + s);
                for (i, mode) in [
                    PlatformMode::CpuOnly,
                    PlatformMode::FpgaOnly,
                    PlatformMode::Hybrid,
                ]
                .iter()
                .enumerate()
                {
                    let r = ranksolve::solve(&inst, *mode, obj, S_INTERVALS);
                    acc[i][0] += r.energy_efficiency(&inst);
                    acc[i][1] += r.relative_cost(&inst);
                }
            }
            let n = ctx.seeds as f64;
            t.row(vec![
                format!("{b}"),
                pct(acc[0][0] / n),
                ratio(acc[0][1] / n),
                pct(acc[1][0] / n),
                ratio(acc[1][1] / n),
                pct(acc[2][0] / n),
                ratio(acc[2][1] / n),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Fig 3: pareto frontier of weighted-objective hybrid schedulers at
/// three burstiness levels.
pub fn fig3(ctx: &ExpCtx) -> Vec<Table> {
    let points = 9;
    let mut t = Table::new(
        "Fig 3: pareto-optimal energy/cost trade-offs (hybrid, weighted objectives)",
        &["b", "w_energy", "Energy Eff.", "Rel. Cost"],
    );
    for &b in &[0.55, 0.65, 0.75] {
        let mut acc = vec![(0.0f64, 0.0f64); points];
        for s in 0..ctx.seeds {
            let inst = instance(ctx, b, 2000 + s);
            for (i, p) in pareto::sweep_persist(&inst, points, S_INTERVALS).iter().enumerate() {
                acc[i].0 += p.energy_efficiency;
                acc[i].1 += p.relative_cost;
            }
        }
        let n = ctx.seeds as f64;
        for (i, (e, c)) in acc.iter().enumerate() {
            let w = i as f64 / (points - 1) as f64;
            t.row(vec![format!("{b}"), sig3(w), pct(e / n), ratio(c / n)]);
        }
    }
    vec![t]
}
