//! Fig 2 and Fig 3: the §3 idealized analysis on the fluid model.
//!
//! Setting (§3.2): hour-long per-second b-model rate traces, 10k req/s
//! average, 10 ms constant requests, paper-default workers, results
//! normalized to the idealized FPGA-only platform and averaged over ten
//! trace runs.
//!
//! Solves parallelize over (burstiness, seed) units via the sweep
//! engine; every unit builds its instance from `Rng::new(seed)` — a pure
//! function of the unit — so results are independent of `--jobs`.

use super::common::ExpCtx;
use super::sweep::parallel_map;
use crate::config::PlatformConfig;
use crate::opt::{pareto, ranksolve, FluidInstance, PlatformMode};
use crate::sched::Objective;
use crate::trace::{bmodel, RateTrace};
use crate::util::rng::Rng;
use crate::util::table::{pct, ratio, sig3, Table};

const BURSTS: &[f64] = &[0.5, 0.55, 0.6, 0.65, 0.7, 0.75];
/// §3 granularity: per-second intervals; the 10 s FPGA spin-up becomes a
/// 10-interval persistence horizon (Table 3's last constraint).
const S_INTERVALS: usize = 10;

fn instance(ctx: &ExpCtx, b: f64, seed: u64) -> FluidInstance {
    let platform = PlatformConfig::paper_default();
    let duration = if ctx.full { 3600 } else { 1800 };
    let rate = 10_000.0;
    let mut rng = Rng::new(seed);
    let rates = RateTrace::new(1.0, bmodel::bmodel_rates(&mut rng, b, duration, rate));
    // dt = 1 s (NOT the spin-up): §3 evaluates at rate granularity.
    FluidInstance::from_rates(&rates, 0.010, 1.0, platform)
}

/// The (burstiness, seed) unit list for a figure, in table-row order.
fn units(bursts: &[f64], seeds: u64) -> Vec<(f64, u64)> {
    bursts
        .iter()
        .flat_map(|&b| (0..seeds).map(move |s| (b, s)))
        .collect()
}

/// Fig 2: energy-optimal (a) and cost-optimal (b) scheduling of CPU-only,
/// FPGA-only, and hybrid platforms vs burstiness.
pub fn fig2(ctx: &ExpCtx) -> Vec<Table> {
    const MODES: [PlatformMode; 3] = [
        PlatformMode::CpuOnly,
        PlatformMode::FpgaOnly,
        PlatformMode::Hybrid,
    ];
    let units = units(BURSTS, ctx.seeds);
    let mut tables = Vec::new();
    for (tag, obj) in [
        ("2a energy-optimal", Objective::energy()),
        ("2b cost-optimal", Objective::cost()),
    ] {
        // One unit = one trace instance solved under all three platform
        // modes; [[eff, cost]; 3] per unit.
        let results = parallel_map(&units, ctx.effective_jobs(), |_, &(b, s)| {
            let inst = instance(ctx, b, 1000 + s);
            MODES.map(|mode| {
                let r = ranksolve::solve(&inst, mode, obj, S_INTERVALS);
                [r.energy_efficiency(&inst), r.relative_cost(&inst)]
            })
        });
        let mut t = Table::new(
            &format!("Fig {tag}: optimal scheduling vs burstiness (normalized to idealized FPGA-only)"),
            &[
                "b",
                "CPU-only eff", "CPU-only cost",
                "FPGA-only eff", "FPGA-only cost",
                "Hybrid eff", "Hybrid cost",
            ],
        );
        let n = ctx.seeds as f64;
        for (group, &b) in results.chunks_exact(ctx.seeds as usize).zip(BURSTS) {
            let mut acc = [[0.0f64; 2]; 3];
            for unit in group {
                for (i, m) in unit.iter().enumerate() {
                    acc[i][0] += m[0];
                    acc[i][1] += m[1];
                }
            }
            t.row(vec![
                format!("{b}"),
                pct(acc[0][0] / n),
                ratio(acc[0][1] / n),
                pct(acc[1][0] / n),
                ratio(acc[1][1] / n),
                pct(acc[2][0] / n),
                ratio(acc[2][1] / n),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Fig 3: pareto frontier of weighted-objective hybrid schedulers at
/// three burstiness levels.
pub fn fig3(ctx: &ExpCtx) -> Vec<Table> {
    let points = 9;
    let bursts = [0.55, 0.65, 0.75];
    let units = units(&bursts, ctx.seeds);
    let results = parallel_map(&units, ctx.effective_jobs(), |_, &(b, s)| {
        let inst = instance(ctx, b, 2000 + s);
        pareto::sweep_persist(&inst, points, S_INTERVALS)
            .into_iter()
            .map(|p| (p.energy_efficiency, p.relative_cost))
            .collect::<Vec<_>>()
    });
    let mut t = Table::new(
        "Fig 3: pareto-optimal energy/cost trade-offs (hybrid, weighted objectives)",
        &["b", "w_energy", "Energy Eff.", "Rel. Cost"],
    );
    let n = ctx.seeds as f64;
    for (group, &b) in results.chunks_exact(ctx.seeds as usize).zip(&bursts) {
        let mut acc = vec![(0.0f64, 0.0f64); points];
        for unit in group {
            for (i, &(e, c)) in unit.iter().enumerate() {
                acc[i].0 += e;
                acc[i].1 += c;
            }
        }
        for (i, (e, c)) in acc.iter().enumerate() {
            let w = i as f64 / (points - 1) as f64;
            t.row(vec![format!("{b}"), sig3(w), pct(e / n), ratio(c / n)]);
        }
    }
    vec![t]
}
