//! Fig 4-7: sensitivity studies on synthetic (b-model per-minute) traces.
//!
//! Each figure declares its whole (parameter × scheduler) grid as a
//! [`SweepGrid`] and executes it in one deterministic parallel pass;
//! result cells come back in push order, so rows render exactly as the
//! paper tables do regardless of `--jobs`.

use super::common::ExpCtx;
use super::sweep::{SweepCell, SweepGrid, WorkloadSpec};
use crate::config::{PlatformConfig, SchedulerKind, SimConfig, SizeBucket};
use crate::util::table::{pct, ratio, sig3, Table};

const BURSTS: &[f64] = &[0.5, 0.55, 0.6, 0.65, 0.7, 0.75];

fn cfg_with_fpga(spin_up: f64, speedup: f64, busy_power: f64) -> SimConfig {
    let mut platform = PlatformConfig::paper_default();
    platform.fpga.spin_up = spin_up;
    platform.fpga.speedup = speedup;
    platform.fpga.busy_power = busy_power;
    SimConfig::from_platform(platform)
}

fn cell(
    ctx: &ExpCtx,
    scheduler: &SchedulerKind,
    cfg: &SimConfig,
    burstiness: f64,
    rate: f64,
    size: f64,
    seed_base: u64,
) -> SweepCell {
    SweepCell {
        scheduler: scheduler.clone(),
        cfg: cfg.clone(),
        workload: WorkloadSpec {
            burstiness,
            rate,
            size,
            duration: ctx.synthetic_duration(),
        },
        seed_base,
        scenario: None,
    }
}

/// Fig 4: Spork vs MArk-ideal under a 60 s spin-up, with CPU-request
/// shares and FPGA spin-up counts (right panel).
pub fn fig4(ctx: &ExpCtx) -> Vec<Table> {
    let cfg = cfg_with_fpga(60.0, 2.0, 50.0);
    let roster = [
        SchedulerKind::MarkIdeal,
        SchedulerKind::spork_c(),
        SchedulerKind::spork_e(),
        SchedulerKind::spork_e_ideal(),
    ];
    let mut grid = SweepGrid::from_ctx(ctx);
    for &b in BURSTS {
        for k in &roster {
            grid.push(cell(ctx, k, &cfg, b, ctx.synthetic_rate(), 0.010, 31));
        }
    }
    let cells = grid.run();

    let mut left = Table::new(
        "Fig 4 (left): energy efficiency and cost vs burstiness @ 60s FPGA spin-up",
        &["b", "Scheduler", "Energy Eff.", "Rel. Cost"],
    );
    let mut right = Table::new(
        "Fig 4 (right): CPU request share and FPGA spin-ups (normalized to row max)",
        &["b", "Scheduler", "CPU req %", "FPGA spin-ups (norm)"],
    );
    for (row, &b) in cells.chunks_exact(roster.len()).zip(BURSTS) {
        let max_spin = row.iter().map(|c| c.fpga_spinups).fold(1.0f64, f64::max);
        for (k, c) in roster.iter().zip(row) {
            left.row(vec![
                format!("{b}"),
                k.display(),
                pct(c.energy_eff),
                ratio(c.rel_cost),
            ]);
            right.row(vec![
                format!("{b}"),
                k.display(),
                pct(c.cpu_req_frac),
                sig3(c.fpga_spinups / max_spin),
            ]);
        }
    }
    vec![left, right]
}

/// Fig 5: burstiness x FPGA spin-up time, four schedulers.
pub fn fig5(ctx: &ExpCtx) -> Vec<Table> {
    let spinups: &[f64] = if ctx.full {
        &[1.0, 10.0, 60.0, 100.0]
    } else {
        &[1.0, 10.0, 60.0]
    };
    let bursts = [0.5, 0.6, 0.7, 0.75];
    let roster = [
        SchedulerKind::CpuDynamic,
        SchedulerKind::FpgaStatic,
        SchedulerKind::FpgaDynamic,
        SchedulerKind::spork_e(),
    ];
    let mut grid = SweepGrid::from_ctx(ctx);
    for &su in spinups {
        let cfg = cfg_with_fpga(su, 2.0, 50.0);
        for &b in &bursts {
            for k in &roster {
                grid.push(cell(ctx, k, &cfg, b, ctx.synthetic_rate(), 0.010, 41));
            }
        }
    }
    let cells = grid.run();

    let mut t = Table::new(
        "Fig 5: sensitivity to burstiness and FPGA spin-up time",
        &["spin-up", "b", "Scheduler", "Energy Eff.", "Rel. Cost"],
    );
    let mut it = cells.iter();
    for &su in spinups {
        for &b in &bursts {
            for k in &roster {
                let c = it.next().expect("grid/table mismatch");
                t.row(vec![
                    format!("{su}s"),
                    format!("{b}"),
                    k.display(),
                    pct(c.energy_eff),
                    ratio(c.rel_cost),
                ]);
            }
        }
    }
    vec![t]
}

/// Fig 6: FPGA speedup x busy power draw (both log-scale axes in the
/// paper).
pub fn fig6(ctx: &ExpCtx) -> Vec<Table> {
    let speedups = [1.0, 2.0, 4.0];
    let powers = [25.0, 50.0, 100.0];
    let roster = [
        SchedulerKind::CpuDynamic,
        SchedulerKind::FpgaStatic,
        SchedulerKind::FpgaDynamic,
        SchedulerKind::spork_e(),
    ];
    let mut grid = SweepGrid::from_ctx(ctx);
    for &speedup in &speedups {
        for &bp in &powers {
            let cfg = cfg_with_fpga(10.0, speedup, bp);
            for k in &roster {
                grid.push(cell(ctx, k, &cfg, 0.6, ctx.synthetic_rate(), 0.010, 51));
            }
        }
    }
    let cells = grid.run();

    let mut t = Table::new(
        "Fig 6: sensitivity to FPGA speedup and busy power (b=0.6, short requests)",
        &["speedup", "busy W", "Scheduler", "Energy Eff.", "Rel. Cost"],
    );
    let mut it = cells.iter();
    for &speedup in &speedups {
        for &bp in &powers {
            for k in &roster {
                let c = it.next().expect("grid/table mismatch");
                t.row(vec![
                    format!("{speedup}x"),
                    format!("{bp}"),
                    k.display(),
                    pct(c.energy_eff),
                    ratio(c.rel_cost),
                ]);
            }
        }
    }
    vec![t]
}

/// Fig 7: request-size buckets (deadlines scale with size).
pub fn fig7(ctx: &ExpCtx) -> Vec<Table> {
    let roster = [
        SchedulerKind::CpuDynamic,
        SchedulerKind::FpgaStatic,
        SchedulerKind::FpgaDynamic,
        SchedulerKind::spork_e(),
    ];
    let cfg = SimConfig::paper_default();
    let buckets = [SizeBucket::Short, SizeBucket::Medium, SizeBucket::Long];
    // Geometric midpoint of each bucket; rate scaled to keep total demand
    // (in workers) constant at 100 x scale, as in §5.1.
    let sizes: Vec<f64> = buckets
        .iter()
        .map(|bucket| {
            let (lo, hi) = bucket.bounds();
            (lo * hi).sqrt()
        })
        .collect();
    let mut grid = SweepGrid::from_ctx(ctx);
    for &size in &sizes {
        let demand_workers = ctx.synthetic_rate() * 0.010; // same demand as short runs
        let rate = demand_workers / size;
        for k in &roster {
            grid.push(cell(ctx, k, &cfg, 0.6, rate, size, 61));
        }
    }
    let cells = grid.run();

    let mut t = Table::new(
        "Fig 7: sensitivity to request sizes (b=0.6; deadline = 10x size)",
        &["bucket", "size", "Scheduler", "Energy Eff.", "Rel. Cost"],
    );
    let mut it = cells.iter();
    for (bucket, &size) in buckets.iter().zip(&sizes) {
        for k in &roster {
            let c = it.next().expect("grid/table mismatch");
            t.row(vec![
                bucket.name().into(),
                format!("{:.3}s", size),
                k.display(),
                pct(c.energy_eff),
                ratio(c.rel_cost),
            ]);
        }
    }
    vec![t]
}
