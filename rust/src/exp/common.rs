//! Shared experiment machinery: run contexts, seed averaging, and
//! multi-app aggregation (§5.1: synthetic results average 10 trace runs;
//! production energy/cost aggregate across applications).

use super::sweep::{self, SweepCell, SweepGrid, WorkloadSpec};
use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::sched::{self, WorkloadProfile};
use crate::sim::{IdealBaseline, Metrics};
use crate::trace::AppTrace;
use std::path::PathBuf;
use std::sync::Arc;

/// CLI-derived experiment context.
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub out_dir: PathBuf,
    /// Synthetic trace repetitions (paper: 10).
    pub seeds: u64,
    /// Production demand scale (1.0 = paper scale; defaults lower to
    /// bound single-core runtimes; recorded in EXPERIMENTS.md).
    pub scale: f64,
    /// Paper-scale workloads (slow).
    pub full: bool,
    /// Sweep worker threads (`--jobs`); 0 = one per available core.
    pub jobs: usize,
}

impl ExpCtx {
    pub fn synthetic_duration(&self) -> f64 {
        if self.full {
            7200.0
        } else {
            3600.0
        }
    }

    pub fn synthetic_rate(&self) -> f64 {
        if self.full {
            1000.0
        } else {
            300.0
        }
    }

    /// The resolved worker count (0 → available cores).
    pub fn effective_jobs(&self) -> usize {
        sweep::effective_jobs(self.jobs)
    }
}

/// Normalized outcome of one (scheduler, workload) cell, averaged over
/// seeds where applicable.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Cell {
    pub energy_eff: f64,
    pub rel_cost: f64,
    pub miss_frac: f64,
    pub cpu_req_frac: f64,
    pub fpga_spinups: f64,
    pub peak_fpgas: f64,
    /// Scenario adversity tallies (all 0.0 on fault-free runs): spot
    /// preemptions, independent worker failures, re-dispatched in-flight
    /// requests, requests abandoned (budget or deadline), and the
    /// partially-executed seconds of work lost to kills.
    pub preemptions: f64,
    pub worker_failures: f64,
    pub redispatches: f64,
    pub abandoned: f64,
    pub work_lost: f64,
    pub runs: u32,
}

impl Cell {
    /// The normalized outcome of a single simulation run. Degenerate
    /// runs (zero requests → zero energy/ideal) read as 0.0, never NaN:
    /// cells are merged and averaged, and one NaN would silently poison
    /// a whole grid row.
    pub fn from_run(metrics: &Metrics, ideal: &IdealBaseline) -> Cell {
        let guard = |num: f64, den: f64| if den > 0.0 { num / den } else { 0.0 };
        Cell {
            energy_eff: guard(ideal.energy, metrics.total_energy()),
            rel_cost: guard(metrics.total_cost(), ideal.cost),
            miss_frac: metrics.deadline_misses as f64 / metrics.requests.max(1) as f64,
            cpu_req_frac: metrics.cpu_request_fraction(),
            fpga_spinups: metrics.fpga_spinups as f64,
            peak_fpgas: metrics.peak_fpgas as f64,
            preemptions: metrics.preemptions as f64,
            worker_failures: metrics.worker_failures as f64,
            redispatches: metrics.redispatches as f64,
            abandoned: metrics.abandoned as f64,
            work_lost: metrics.work_lost,
            runs: 1,
        }
    }

    /// Merge another cell's (possibly multi-run) sums into this one. The
    /// sweep engine merges per-replicate cells in a fixed order, so
    /// averages are bit-identical regardless of execution parallelism.
    pub fn merge(&mut self, other: &Cell) {
        self.energy_eff += other.energy_eff;
        self.rel_cost += other.rel_cost;
        self.miss_frac += other.miss_frac;
        self.cpu_req_frac += other.cpu_req_frac;
        self.fpga_spinups += other.fpga_spinups;
        self.peak_fpgas += other.peak_fpgas;
        self.preemptions += other.preemptions;
        self.worker_failures += other.worker_failures;
        self.redispatches += other.redispatches;
        self.abandoned += other.abandoned;
        self.work_lost += other.work_lost;
        self.runs += other.runs;
    }

    /// Accumulate one run in place (kept for call sites that aggregate
    /// metrics themselves; equivalent to merging [`Cell::from_run`]).
    pub fn add_run(&mut self, metrics: &Metrics, ideal: &IdealBaseline) {
        self.merge(&Cell::from_run(metrics, ideal));
    }

    /// Convert accumulated sums into per-run averages.
    pub fn finish(mut self) -> Cell {
        let n = self.runs.max(1) as f64;
        self.energy_eff /= n;
        self.rel_cost /= n;
        self.miss_frac /= n;
        self.cpu_req_frac /= n;
        self.fpga_spinups /= n;
        self.peak_fpgas /= n;
        self.preemptions /= n;
        self.worker_failures /= n;
        self.redispatches /= n;
        self.abandoned /= n;
        self.work_lost /= n;
        self
    }
}

/// Run `kind` on one synthetic workload per seed and average — a
/// single-cell [`SweepGrid`] (replicates run in parallel under
/// `ctx.jobs`, deterministically).
#[allow(clippy::too_many_arguments)]
pub fn run_synthetic(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    ctx: &ExpCtx,
    burstiness: f64,
    rate: f64,
    size: f64,
    duration: f64,
    seed_base: u64,
) -> Cell {
    let mut grid = SweepGrid::from_ctx(ctx);
    grid.push(SweepCell {
        scheduler: kind.clone(),
        cfg: cfg.clone(),
        workload: WorkloadSpec {
            burstiness,
            rate,
            size,
            duration,
        },
        seed_base,
        scenario: None,
    });
    grid.run().pop().expect("single-cell grid")
}

/// Run `kind` over a multi-app production workload: each app gets its own
/// pool + a fitted policy instance from the `sched::build` factory;
/// energy/cost aggregate across apps before normalizing (§5.2). Apps
/// share no state, so they fan out across the process-wide bounded
/// executor (DESIGN.md §14) with metrics merged in fixed app-index
/// order — bit-identical to the serial loop for any `--jobs`, and the
/// fan-out degrades to that serial loop whenever an outer grid holds
/// the permit pool.
pub fn run_production(kind: &SchedulerKind, cfg: &SimConfig, apps: &[AppTrace]) -> Cell {
    run_production_jobs(kind, cfg, apps, 0)
}

/// [`run_production`] with an explicit per-call worker cap (`0` = let
/// the executor's budget decide; `1` = force the inline serial loop —
/// the reference plan the parity tests compare against).
pub fn run_production_jobs(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    apps: &[AppTrace],
    jobs: usize,
) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let per_app = crate::util::executor::Executor::global().map(apps, jobs, |_, app| {
        sched::run_scheduler(kind, app, cfg, &defaults).metrics
    });
    let mut total = Metrics::default();
    for m in &per_app {
        total.merge(m);
    }
    let ideal = IdealBaseline::for_work(total.total_work, &defaults);
    Cell::from_run(&total, &ideal).finish()
}

/// Profile a multi-app workload once, so a whole scheduler roster can
/// share the per-app interval bins and arrival counts (Table 8 runs ~8
/// kinds over the same apps; without this each kind re-streams every
/// app's arrivals for its oracle and fitting searches).
pub fn profile_apps(apps: Vec<AppTrace>, cfg: &SimConfig) -> Vec<WorkloadProfile> {
    apps.into_iter()
        .map(|app| WorkloadProfile::new(Arc::new(app), cfg.interval))
        .collect()
}

/// [`run_production`] over pre-profiled apps — bit-identical results
/// (pinned by `rust/tests/fit_parity.rs`), minus the per-kind synthesis
/// and oracle re-streaming. Fans out per app like [`run_production`].
pub fn run_production_profiles(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    profiles: &[WorkloadProfile],
) -> Cell {
    run_production_profiles_jobs(kind, cfg, profiles, 0)
}

/// [`run_production_profiles`] with an explicit per-call worker cap
/// (see [`run_production_jobs`]).
pub fn run_production_profiles_jobs(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    profiles: &[WorkloadProfile],
    jobs: usize,
) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let per_app = crate::util::executor::Executor::global().map(profiles, jobs, |_, profile| {
        sched::run_scheduler_profile(kind, profile, cfg, &defaults).metrics
    });
    let mut total = Metrics::default();
    for m in &per_app {
        total.merge(m);
    }
    let ideal = IdealBaseline::for_work(total.total_work, &defaults);
    Cell::from_run(&total, &ideal).finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EnergyBreakdown;

    fn metrics(busy: f64, cost: f64, reqs: u64, misses: u64) -> Metrics {
        let mut m = Metrics::default();
        m.fpga_energy = EnergyBreakdown {
            busy,
            ..Default::default()
        };
        m.fpga_cost = cost;
        m.requests = reqs;
        m.deadline_misses = misses;
        m.total_work = 1.0;
        m
    }

    #[test]
    fn cell_averages_runs() {
        let ideal = IdealBaseline {
            energy: 50.0,
            cost: 1.0,
        };
        let mut c = Cell::default();
        c.add_run(&metrics(100.0, 2.0, 10, 1), &ideal); // eff 0.5, cost 2
        c.add_run(&metrics(50.0, 4.0, 10, 3), &ideal); // eff 1.0, cost 4
        let c = c.finish();
        assert!((c.energy_eff - 0.75).abs() < 1e-12);
        assert!((c.rel_cost - 3.0).abs() < 1e-12);
        assert!((c.miss_frac - 0.2).abs() < 1e-12);
        assert_eq!(c.runs, 2);
    }

    #[test]
    fn degenerate_run_yields_zero_ratios_not_nan() {
        let c = Cell::from_run(
            &Metrics::default(),
            &IdealBaseline {
                energy: 0.0,
                cost: 0.0,
            },
        );
        assert_eq!(c.energy_eff, 0.0);
        assert_eq!(c.rel_cost, 0.0);
        assert_eq!(c.miss_frac, 0.0);
        // Averaging with a real run stays finite.
        let mut m = Cell::default();
        m.merge(&c);
        assert!(m.finish().energy_eff.is_finite());
    }

    #[test]
    fn cell_merge_equals_sequential_add() {
        let ideal = IdealBaseline {
            energy: 50.0,
            cost: 1.0,
        };
        let runs = [metrics(100.0, 2.0, 10, 1), metrics(50.0, 4.0, 10, 3)];
        let mut seq = Cell::default();
        for m in &runs {
            seq.add_run(m, &ideal);
        }
        let mut merged = Cell::default();
        for m in &runs {
            merged.merge(&Cell::from_run(m, &ideal));
        }
        assert_eq!(seq, merged);
    }

    #[test]
    fn synthetic_runner_deterministic() {
        let ctx = ExpCtx {
            out_dir: PathBuf::from("/tmp"),
            seeds: 2,
            scale: 1.0,
            full: false,
            jobs: 0,
        };
        let cfg = SimConfig::paper_default();
        let a = run_synthetic(
            &SchedulerKind::CpuDynamic,
            &cfg,
            &ctx,
            0.6,
            100.0,
            0.010,
            300.0,
            1,
        );
        let b = run_synthetic(
            &SchedulerKind::CpuDynamic,
            &cfg,
            &ctx,
            0.6,
            100.0,
            0.010,
            300.0,
            1,
        );
        assert_eq!(a.energy_eff, b.energy_eff);
        assert_eq!(a.rel_cost, b.rel_cost);
    }
}
