//! Shared experiment machinery: run contexts, seed averaging, and
//! multi-app aggregation (§5.1: synthetic results average 10 trace runs;
//! production energy/cost aggregate across applications).

use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::sched;
use crate::sim::{IdealBaseline, Metrics};
use crate::trace::AppTrace;
use crate::util::rng::Rng;
use std::path::PathBuf;

/// CLI-derived experiment context.
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub out_dir: PathBuf,
    /// Synthetic trace repetitions (paper: 10).
    pub seeds: u64,
    /// Production demand scale (1.0 = paper scale; defaults lower to
    /// bound single-core runtimes; recorded in EXPERIMENTS.md).
    pub scale: f64,
    /// Paper-scale workloads (slow).
    pub full: bool,
}

impl ExpCtx {
    pub fn synthetic_duration(&self) -> f64 {
        if self.full {
            7200.0
        } else {
            3600.0
        }
    }

    pub fn synthetic_rate(&self) -> f64 {
        if self.full {
            1000.0
        } else {
            300.0
        }
    }
}

/// Normalized outcome of one (scheduler, workload) cell, averaged over
/// seeds where applicable.
#[derive(Clone, Debug, Default)]
pub struct Cell {
    pub energy_eff: f64,
    pub rel_cost: f64,
    pub miss_frac: f64,
    pub cpu_req_frac: f64,
    pub fpga_spinups: f64,
    pub peak_fpgas: f64,
    pub runs: u32,
}

impl Cell {
    pub fn add_run(&mut self, metrics: &Metrics, ideal: &IdealBaseline) {
        self.energy_eff += ideal.energy / metrics.total_energy();
        self.rel_cost += metrics.total_cost() / ideal.cost;
        self.miss_frac += metrics.deadline_misses as f64 / metrics.requests.max(1) as f64;
        self.cpu_req_frac += metrics.cpu_request_fraction();
        self.fpga_spinups += metrics.fpga_spinups as f64;
        self.peak_fpgas += metrics.peak_fpgas as f64;
        self.runs += 1;
    }

    pub fn finish(mut self) -> Cell {
        let n = self.runs.max(1) as f64;
        self.energy_eff /= n;
        self.rel_cost /= n;
        self.miss_frac /= n;
        self.cpu_req_frac /= n;
        self.fpga_spinups /= n;
        self.peak_fpgas /= n;
        self
    }
}

/// Run `kind` on one synthetic workload per seed and average.
pub fn run_synthetic(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    ctx: &ExpCtx,
    burstiness: f64,
    rate: f64,
    size: f64,
    duration: f64,
    seed_base: u64,
) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let mut cell = Cell::default();
    for s in 0..ctx.seeds {
        let mut rng = Rng::new(seed_base + s);
        let trace =
            crate::trace::synthetic_app("exp", &mut rng, burstiness, duration, rate, size);
        let r = sched::run_scheduler(kind, &trace, cfg, &defaults);
        cell.add_run(&r.metrics, &r.ideal);
    }
    cell.finish()
}

/// Run `kind` over a multi-app production workload: each app gets its own
/// pool + scheduler instance; energy/cost aggregate across apps before
/// normalizing (§5.2).
pub fn run_production(kind: &SchedulerKind, cfg: &SimConfig, apps: &[AppTrace]) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let mut total = Metrics::default();
    for app in apps {
        let r = sched::run_scheduler(kind, app, cfg, &defaults);
        total.merge(&r.metrics);
    }
    let ideal = IdealBaseline::for_work(total.total_work, &defaults);
    let mut cell = Cell::default();
    cell.add_run(&total, &ideal);
    cell.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EnergyBreakdown;

    fn metrics(busy: f64, cost: f64, reqs: u64, misses: u64) -> Metrics {
        let mut m = Metrics::default();
        m.fpga_energy = EnergyBreakdown {
            busy,
            ..Default::default()
        };
        m.fpga_cost = cost;
        m.requests = reqs;
        m.deadline_misses = misses;
        m.total_work = 1.0;
        m
    }

    #[test]
    fn cell_averages_runs() {
        let ideal = IdealBaseline {
            energy: 50.0,
            cost: 1.0,
        };
        let mut c = Cell::default();
        c.add_run(&metrics(100.0, 2.0, 10, 1), &ideal); // eff 0.5, cost 2
        c.add_run(&metrics(50.0, 4.0, 10, 3), &ideal); // eff 1.0, cost 4
        let c = c.finish();
        assert!((c.energy_eff - 0.75).abs() < 1e-12);
        assert!((c.rel_cost - 3.0).abs() < 1e-12);
        assert!((c.miss_frac - 0.2).abs() < 1e-12);
        assert_eq!(c.runs, 2);
    }

    #[test]
    fn synthetic_runner_deterministic() {
        let ctx = ExpCtx {
            out_dir: PathBuf::from("/tmp"),
            seeds: 2,
            scale: 1.0,
            full: false,
        };
        let cfg = SimConfig::paper_default();
        let a = run_synthetic(
            &SchedulerKind::CpuDynamic,
            &cfg,
            &ctx,
            0.6,
            100.0,
            0.010,
            300.0,
            1,
        );
        let b = run_synthetic(
            &SchedulerKind::CpuDynamic,
            &cfg,
            &ctx,
            0.6,
            100.0,
            0.010,
            300.0,
            1,
        );
        assert_eq!(a.energy_eff, b.energy_eff);
        assert_eq!(a.rel_cost, b.rel_cost);
    }
}
