//! `spork bench-sim`: the simulator-throughput trajectory harness.
//!
//! Replays a large (default 1M-arrival) synthetic trace through the
//! streaming sim path (`sched::build_source` + `sim::run_source`, with
//! any fitting passes excluded from the timer) and reports arrivals/sec
//! plus a peak-RSS proxy to `BENCH_sim_throughput.json`.
//! The workload streams from its `(seed, 0)` RNG, so memory stays
//! bounded by pool size + pending events no matter how many arrivals
//! replay — the point the bench exists to keep true. CI runs a reduced-N
//! smoke configuration and uploads the JSON as a per-PR artifact, so
//! throughput or memory regressions are visible in review.

use crate::cli::Args;
use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::sched;
use crate::sim;
use crate::trace::{synthetic_source, ArrivalSource};
use crate::util::rng::Rng;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchSimReport {
    pub scheduler: String,
    /// Arrivals actually replayed (Poisson sampling jitters around the
    /// target).
    pub arrivals: u64,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub arrivals_per_sec: f64,
    /// Peak resident set size in kB (Linux `VmHWM`; 0 where unavailable).
    /// A process-lifetime high-water mark — an upper bound on what the
    /// replay itself needed.
    pub peak_rss_kb: u64,
    pub deadline_misses: u64,
}

impl BenchSimReport {
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"arrivals\": {},\n  \
             \"sim_seconds\": {:.3},\n  \"wall_seconds\": {:.3},\n  \
             \"arrivals_per_sec\": {:.1},\n  \"peak_rss_kb\": {},\n  \
             \"deadline_misses\": {}\n}}\n",
            self.scheduler,
            self.arrivals,
            self.sim_seconds,
            self.wall_seconds,
            self.arrivals_per_sec,
            self.peak_rss_kb,
            self.deadline_misses,
        )
    }
}

/// Peak resident set size (`VmHWM`) in kB. Linux-only proc parse; returns
/// 0 on other platforms (the JSON field then just reads as "unknown").
pub fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// Replay `target_arrivals` synthetic arrivals (rate `rate` req/s,
/// b = 0.65, 10 ms requests) through `kind` on the streaming path and
/// time it end-to-end.
pub fn run_bench_sim(
    kind: &SchedulerKind,
    target_arrivals: u64,
    rate: f64,
    seed: u64,
) -> BenchSimReport {
    let duration = target_arrivals as f64 / rate;
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    // The factory owns only Copy parameters, so it is 'static and
    // re-creatable for however many passes the kind needs.
    let make = move || -> Box<dyn ArrivalSource> {
        Box::new(synthetic_source(
            "bench",
            Rng::for_stream(seed, 0),
            0.65,
            duration,
            rate,
            0.010,
            60.0,
        ))
    };
    // Build (including any fitting/oracle passes) outside the timer so
    // arrivals_per_sec measures exactly one streaming replay for every
    // kind — fitted kinds would otherwise amortize up to 9 untracked
    // passes into the reported throughput.
    let mut policy = sched::build_source(kind, &cfg, &make);
    let t0 = Instant::now();
    let r = sim::run_source(make(), cfg.clone(), &defaults, policy.as_mut());
    let wall = t0.elapsed().as_secs_f64();
    BenchSimReport {
        scheduler: r.scheduler.clone(),
        arrivals: r.metrics.requests,
        sim_seconds: duration,
        wall_seconds: wall,
        arrivals_per_sec: r.metrics.requests as f64 / wall.max(1e-9),
        peak_rss_kb: peak_rss_kb(),
        deadline_misses: r.metrics.deadline_misses,
    }
}

/// `spork bench-sim` CLI entrypoint.
pub fn cmd_bench_sim(args: &Args) -> Result<(), String> {
    let arrivals = args.u64_or("arrivals", 1_000_000)?;
    let rate = args.f64_or("rate", 2000.0)?;
    if arrivals == 0 {
        return Err("--arrivals must be > 0".into());
    }
    if !(rate > 0.0 && rate.is_finite()) {
        return Err("--rate must be a finite positive number".into());
    }
    let seed = args.u64_or("seed", 1)?;
    let out = args.str_or("out", "BENCH_sim_throughput.json");
    let name = args.str_or("scheduler", "spork-e");
    let kind = SchedulerKind::from_name(&name)
        .ok_or(format!("unknown scheduler '{name}'"))?;
    eprintln!(
        "replaying ~{arrivals} arrivals at {rate} req/s through {} (streaming)...",
        kind.display()
    );
    let report = run_bench_sim(&kind, arrivals, rate, seed);
    let json = report.to_json();
    std::fs::write(&out, &json).map_err(|e| format!("writing {out}: {e}"))?;
    println!(
        "{} arrivals in {:.2}s = {:.0} arrivals/s (peak RSS {} kB, {} misses) -> {}",
        report.arrivals,
        report.wall_seconds,
        report.arrivals_per_sec,
        report.peak_rss_kb,
        report.deadline_misses,
        out
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_bench_runs_and_reports() {
        let r = run_bench_sim(&SchedulerKind::spork_e(), 5_000, 500.0, 7);
        assert_eq!(r.scheduler, "spork-e");
        // Poisson jitter: within 20% of target.
        assert!(
            (r.arrivals as f64 - 5_000.0).abs() < 1_000.0,
            "arrivals {}",
            r.arrivals
        );
        assert!(r.arrivals_per_sec > 0.0);
        let j = r.to_json();
        assert!(j.contains("\"arrivals_per_sec\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run_bench_sim(&SchedulerKind::spork_e(), 2_000, 400.0, 3);
        let b = run_bench_sim(&SchedulerKind::spork_e(), 2_000, 400.0, 3);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.deadline_misses, b.deadline_misses);
    }
}
