//! `spork bench-sim`: the simulator-throughput trajectory harness.
//!
//! Replays a large (default 1M-arrival) synthetic trace through the
//! streaming sim path (`sched::build_source` + `sim::run_source`, with
//! any fitting passes excluded from the timer) and reports arrivals/sec
//! plus a peak-RSS proxy to `BENCH_sim_throughput.json`.
//! The workload streams from its `(seed, 0)` RNG, so memory stays
//! bounded by pool size + pending events no matter how many arrivals
//! replay — the point the bench exists to keep true. CI runs a reduced-N
//! smoke configuration and uploads the JSON as a per-PR artifact, so
//! throughput or memory regressions are visible in review.
//!
//! A second axis replays against *pinned fleets* of ~100 / 1k / 10k
//! workers ([`run_pool_scaling`]): per-arrival dispatch cost is the
//! hot-path term that scales with fleet size, and the indexed dispatch
//! queries keep it O(log W). The `pool_scaling` JSON array reports
//! arrivals/sec per fleet size; `--assert-scaling R` fails the run when
//! per-arrival cost at the largest fleet exceeds R× the smallest — the
//! loud CI tripwire for an accidental return to O(W) scans (a linear
//! scan is ~100× from 100 to 10k workers).

use crate::cli::Args;
use crate::config::{DispatchPolicy, PlatformConfig, SchedulerKind, SimConfig, WorkerKind};
use crate::policy::{Action, Observation, Policy, PolicyView, Target};
use crate::sched::{self, dispatch::Dispatcher};
use crate::sim;
use crate::trace::{synthetic_source, ArrivalSource};
use crate::util::rng::Rng;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchSimReport {
    pub scheduler: String,
    /// Arrivals actually replayed (Poisson sampling jitters around the
    /// target).
    pub arrivals: u64,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub arrivals_per_sec: f64,
    /// Peak resident set size in kB (Linux `VmHWM`; 0 where unavailable).
    /// A process-lifetime high-water mark — an upper bound on what the
    /// replay itself needed.
    pub peak_rss_kb: u64,
    pub deadline_misses: u64,
    /// Pool-size scaling axis (empty when not measured).
    pub pool_scaling: Vec<PoolScalePoint>,
}

/// One point of the pool-size scaling axis: a pinned fleet of `workers`
/// serving an arrival stream sized to keep per-worker load constant.
#[derive(Debug, Clone)]
pub struct PoolScalePoint {
    pub workers: u32,
    pub arrivals: u64,
    pub wall_seconds: f64,
    pub arrivals_per_sec: f64,
}

impl PoolScalePoint {
    /// Wall-clock cost per replayed arrival (seconds).
    pub fn per_arrival(&self) -> f64 {
        self.wall_seconds / self.arrivals.max(1) as f64
    }
}

impl BenchSimReport {
    pub fn to_json(&self) -> String {
        let scaling: Vec<String> = self
            .pool_scaling
            .iter()
            .map(|p| {
                format!(
                    "    {{\"workers\": {}, \"arrivals\": {}, \
                     \"wall_seconds\": {:.3}, \"arrivals_per_sec\": {:.1}}}",
                    p.workers, p.arrivals, p.wall_seconds, p.arrivals_per_sec
                )
            })
            .collect();
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"arrivals\": {},\n  \
             \"sim_seconds\": {:.3},\n  \"wall_seconds\": {:.3},\n  \
             \"arrivals_per_sec\": {:.1},\n  \"peak_rss_kb\": {},\n  \
             \"deadline_misses\": {},\n  \"pool_scaling\": [\n{}\n  ]\n}}\n",
            self.scheduler,
            self.arrivals,
            self.sim_seconds,
            self.wall_seconds,
            self.arrivals_per_sec,
            self.peak_rss_kb,
            self.deadline_misses,
            scaling.join(",\n"),
        )
    }
}

/// Peak resident set size (`VmHWM`) in kB. Linux-only proc parse; returns
/// 0 on other platforms (the JSON field then just reads as "unknown").
pub fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// Replay `target_arrivals` synthetic arrivals (rate `rate` req/s,
/// b = 0.65, 10 ms requests) through `kind` on the streaming path and
/// time it end-to-end.
pub fn run_bench_sim(
    kind: &SchedulerKind,
    target_arrivals: u64,
    rate: f64,
    seed: u64,
) -> BenchSimReport {
    let duration = target_arrivals as f64 / rate;
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    // The factory owns only Copy parameters, so it is 'static and
    // re-creatable for however many passes the kind needs.
    let make = move || -> Box<dyn ArrivalSource> {
        Box::new(synthetic_source(
            "bench",
            Rng::for_stream(seed, 0),
            0.65,
            duration,
            rate,
            0.010,
            60.0,
        ))
    };
    // Build (including any fitting/oracle passes) outside the timer so
    // arrivals_per_sec measures exactly one streaming replay for every
    // kind — fitted kinds would otherwise amortize up to 9 untracked
    // passes into the reported throughput.
    let mut policy = sched::build_source(kind, &cfg, &make);
    let t0 = Instant::now();
    let r = sim::run_source(make(), cfg.clone(), &defaults, policy.as_mut());
    let wall = t0.elapsed().as_secs_f64();
    BenchSimReport {
        scheduler: r.scheduler.clone(),
        arrivals: r.metrics.requests,
        sim_seconds: duration,
        wall_seconds: wall,
        arrivals_per_sec: r.metrics.requests as f64 / wall.max(1e-9),
        peak_rss_kb: peak_rss_kb(),
        deadline_misses: r.metrics.deadline_misses,
        pool_scaling: Vec::new(),
    }
}

/// A statically provisioned fleet that exists only to measure dispatch:
/// pre-warms `cpus + fpgas` workers at t = 0, keeps them alive while the
/// trace is live, and routes every arrival through [`Dispatcher::find`]
/// over the full fleet — so per-arrival cost is dominated by exactly the
/// term the pool-scaling axis tracks.
struct PinnedFleet {
    cpus: u32,
    fpgas: u32,
    dispatcher: Dispatcher,
}

impl PinnedFleet {
    fn new(cpus: u32, fpgas: u32) -> Self {
        Self {
            cpus,
            fpgas,
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

impl Policy for PinnedFleet {
    fn name(&self) -> String {
        "pinned-fleet".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &[WorkerKind::Fpga, WorkerKind::Cpu];
        match obs {
            Observation::Start => {
                out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: self.fpgas,
                    prewarmed: true,
                });
                out.push(Action::Alloc {
                    kind: WorkerKind::Cpu,
                    n: self.cpus,
                    prewarmed: true,
                });
            }
            Observation::Arrival { req } => {
                let to = match self.dispatcher.find(view, &req, KINDS) {
                    Some(w) => Target::Worker(w),
                    // Caps equal the fleet, so this falls back to the
                    // earliest-finishing worker instead of growing.
                    None => Target::Fresh(WorkerKind::Cpu),
                };
                out.push(Action::Dispatch { req, to });
            }
            Observation::IdleExpired { worker } => {
                if view.trace_live() {
                    out.push(Action::KeepAlive { worker });
                }
            }
            _ => {}
        }
    }
}

/// Replay `arrivals_each` arrivals against a pinned fleet of each size in
/// `sizes` (per-worker load held constant at ~20 req/s of 10 ms work, so
/// only the fleet dimension varies) and time the replays. Idle timeouts
/// are pinned to the replay window so event traffic doesn't scale with
/// fleet size — the measured axis is dispatch cost.
pub fn run_pool_scaling(sizes: &[u32], arrivals_each: u64, seed: u64) -> Vec<PoolScalePoint> {
    let defaults = PlatformConfig::paper_default();
    let mut points = Vec::new();
    for &workers in sizes {
        let fpgas = (workers / 2).max(1);
        let cpus = (workers - fpgas).max(1);
        let rate = workers as f64 * 20.0;
        let duration = arrivals_each as f64 / rate;
        let mut cfg = SimConfig::paper_default();
        cfg.max_fpgas = Some(fpgas);
        cfg.max_cpus = Some(cpus);
        // One idle-expiry consult per worker after the window, not a
        // per-5ms KeepAlive storm across a 10k-CPU fleet.
        cfg.cpu_idle_timeout = duration.max(1.0);
        cfg.fpga_idle_timeout = duration.max(1.0);
        let source = synthetic_source(
            "scale",
            Rng::for_stream(seed, workers as u64),
            0.65,
            duration,
            rate,
            0.010,
            60.0,
        );
        let mut policy = PinnedFleet::new(cpus, fpgas);
        let t0 = Instant::now();
        let r = sim::run_source(Box::new(source), cfg, &defaults, &mut policy);
        let wall = t0.elapsed().as_secs_f64();
        points.push(PoolScalePoint {
            workers,
            arrivals: r.metrics.requests,
            wall_seconds: wall,
            arrivals_per_sec: r.metrics.requests as f64 / wall.max(1e-9),
        });
    }
    points
}

/// `spork bench-sim` CLI entrypoint.
pub fn cmd_bench_sim(args: &Args) -> Result<(), String> {
    let arrivals = args.u64_or("arrivals", 1_000_000)?;
    let rate = args.f64_or("rate", 2000.0)?;
    if arrivals == 0 {
        return Err("--arrivals must be > 0".into());
    }
    if !(rate > 0.0 && rate.is_finite()) {
        return Err("--rate must be a finite positive number".into());
    }
    let seed = args.u64_or("seed", 1)?;
    let out = args.str_or("out", "BENCH_sim_throughput.json");
    let name = args.str_or("scheduler", "spork-e");
    let kind = SchedulerKind::from_name(&name)
        .ok_or(format!("unknown scheduler '{name}'"))?;
    let sizes = parse_pool_sizes(&args.str_or("pool-sizes", "100,1000,10000"))?;
    let scaling_arrivals = args.u64_or("scaling-arrivals", 200_000)?;
    let assert_scaling = match args.get("assert-scaling") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-scaling: invalid ratio '{v}'"))?,
        ),
        None => None,
    };
    eprintln!(
        "replaying ~{arrivals} arrivals at {rate} req/s through {} (streaming)...",
        kind.display()
    );
    let mut report = run_bench_sim(&kind, arrivals, rate, seed);
    if !sizes.is_empty() && scaling_arrivals > 0 {
        eprintln!(
            "pool-scaling axis: ~{scaling_arrivals} arrivals per fleet size {sizes:?}..."
        );
        report.pool_scaling = run_pool_scaling(&sizes, scaling_arrivals, seed);
    }
    let json = report.to_json();
    std::fs::write(&out, &json).map_err(|e| format!("writing {out}: {e}"))?;
    println!(
        "{} arrivals in {:.2}s = {:.0} arrivals/s (peak RSS {} kB, {} misses) -> {}",
        report.arrivals,
        report.wall_seconds,
        report.arrivals_per_sec,
        report.peak_rss_kb,
        report.deadline_misses,
        out
    );
    for p in &report.pool_scaling {
        println!(
            "  pool {:>6} workers: {} arrivals in {:.2}s = {:.0} arrivals/s",
            p.workers, p.arrivals, p.wall_seconds, p.arrivals_per_sec
        );
    }
    if let Some(cap) = assert_scaling {
        let (small, large) = match (report.pool_scaling.first(), report.pool_scaling.last()) {
            (Some(s), Some(l)) if s.workers < l.workers => (s, l),
            _ => return Err("--assert-scaling needs >= 2 ascending --pool-sizes".into()),
        };
        let ratio = large.per_arrival() / small.per_arrival().max(1e-12);
        println!(
            "  per-arrival cost growth {}->{} workers: {ratio:.2}x (cap {cap}x)",
            small.workers, large.workers
        );
        if ratio > cap {
            return Err(format!(
                "dispatch cost scaling regression: per-arrival cost grew {ratio:.2}x \
                 from {} to {} workers (cap {cap}x) — an O(fleet) scan is back on \
                 the arrival hot path",
                small.workers, large.workers
            ));
        }
    }
    Ok(())
}

/// Parse a `--pool-sizes` comma list ("100,1000,10000").
fn parse_pool_sizes(spec: &str) -> Result<Vec<u32>, String> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Ok(Vec::new());
    }
    spec.split(',')
        .map(|t| {
            let t = t.trim();
            match t.parse::<u32>() {
                Ok(n) if n > 0 => Ok(n),
                _ => Err(format!("--pool-sizes: invalid fleet size '{t}'")),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_bench_runs_and_reports() {
        let r = run_bench_sim(&SchedulerKind::spork_e(), 5_000, 500.0, 7);
        assert_eq!(r.scheduler, "spork-e");
        // Poisson jitter: within 20% of target.
        assert!(
            (r.arrivals as f64 - 5_000.0).abs() < 1_000.0,
            "arrivals {}",
            r.arrivals
        );
        assert!(r.arrivals_per_sec > 0.0);
        let j = r.to_json();
        assert!(j.contains("\"arrivals_per_sec\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run_bench_sim(&SchedulerKind::spork_e(), 2_000, 400.0, 3);
        let b = run_bench_sim(&SchedulerKind::spork_e(), 2_000, 400.0, 3);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.deadline_misses, b.deadline_misses);
    }

    #[test]
    fn pool_scaling_replays_every_size_and_serializes() {
        let points = run_pool_scaling(&[8, 32], 1_500, 11);
        assert_eq!(points.len(), 2);
        for p in &points {
            // Poisson jitter around the per-size target.
            assert!(
                (p.arrivals as f64 - 1_500.0).abs() < 600.0,
                "arrivals {} at {} workers",
                p.arrivals,
                p.workers
            );
            assert!(p.arrivals_per_sec > 0.0);
        }
        let mut r = run_bench_sim(&SchedulerKind::spork_e(), 1_000, 400.0, 3);
        r.pool_scaling = points;
        let j = r.to_json();
        assert!(j.contains("\"pool_scaling\""));
        assert!(j.contains("\"workers\": 32"));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn pool_sizes_parse() {
        assert_eq!(parse_pool_sizes("100, 1000,10000").unwrap(), vec![100, 1000, 10000]);
        assert_eq!(parse_pool_sizes("").unwrap(), Vec::<u32>::new());
        assert!(parse_pool_sizes("12,oops").is_err());
        assert!(parse_pool_sizes("0").is_err());
    }
}
