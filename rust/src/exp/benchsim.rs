//! `spork bench-sim`: the simulator-throughput trajectory harness.
//!
//! Replays a large (default 1M-arrival) synthetic trace through the
//! streaming sim path (`sched::build_source` + `sim::run_source`, with
//! any fitting passes excluded from the timer) and reports arrivals/sec
//! plus a peak-RSS proxy to `BENCH_sim_throughput.json`.
//! The workload streams from its `(seed, 0)` RNG, so memory stays
//! bounded by pool size + pending events no matter how many arrivals
//! replay — the point the bench exists to keep true. CI runs a reduced-N
//! smoke configuration and uploads the JSON as a per-PR artifact, so
//! throughput or memory regressions are visible in review.
//!
//! A second axis replays against *pinned fleets* of ~100 / 1k / 10k
//! workers ([`run_pool_scaling`]): per-arrival dispatch cost is the
//! hot-path term that scales with fleet size, and the indexed dispatch
//! queries keep it O(log W). The `pool_scaling` JSON array reports
//! arrivals/sec per fleet size; `--assert-scaling R` fails the run when
//! per-arrival cost at the largest fleet exceeds R× the smallest — the
//! loud CI tripwire for an accidental return to O(W) scans (a linear
//! scan is ~100× from 100 to 10k workers).
//!
//! A third axis (`--fit`, [`run_fit_bench`]) measures the §5.1 fitting
//! searches on *both* engines — the lockstep default (candidate batches
//! share one stream traversal) and the serial gallop+bisect — reporting
//! batches per search, arrivals simulated per candidate (aborted vs
//! full), and per-batch wall time to `BENCH_fit_passes.json`. Two
//! tripwires guard it: `--assert-fit-abort F` fails the run when even
//! the most cheaply refuted aborted candidate streamed more than
//! fraction `F` of the trace (early abort stopped cutting infeasible
//! passes short), and `--assert-fit-passes P` fails when a lockstep
//! search cost more than `P` full-trace-equivalent stream traversals
//! (the lockstep batching regressed toward one traversal per probe).
//!
//! A fourth axis (`--par-apps`, [`run_par_apps_bench`]) times one
//! multi-app production cell through `run_production` at `--jobs` 1, 2,
//! and 0 (DESIGN.md §14: per-app fan-out over the process-wide bounded
//! executor), asserts the three cells bit-identical before reporting
//! any timing, and writes the wall-clock points to
//! `BENCH_par_apps.json`. `--assert-par-overhead R` fails the run when
//! the parallel cell (jobs = 0) is more than R× *slower* than the
//! serial one — a no-regression gate rather than a speedup gate,
//! because CI runners may expose as few as two cores.

use super::common::run_production_jobs;
use crate::cli::Args;
use crate::config::{
    DispatchPolicy, PlatformConfig, SchedulerKind, SimConfig, SizeBucket, WorkerKind,
};
use crate::policy::{Action, Observation, Policy, PolicyView, Target};
use crate::scenario::{FaultPlan, ScenarioConfig};
use crate::sched::{self, dispatch::Dispatcher, FitEngine, FitStats};
use crate::sim;
use crate::trace::production::{self, Dataset, ProductionParams};
use crate::trace::{synthetic_source, ArrivalSource};
use crate::util::executor::Executor;
use crate::util::rng::Rng;
use std::time::Instant;

/// One §5.1 fitting search measured by the `--fit` axis.
#[derive(Debug, Clone)]
pub struct FitSearchReport {
    pub scheduler: String,
    /// Fitted value (fleet size for fpga-static, headroom multiple k for
    /// fpga-dynamic).
    pub fitted: u32,
    pub wall_seconds: f64,
    pub stats: FitStats,
}

/// The `spork bench-sim --fit` axis: what the fitting searches cost in
/// passes and arrivals, written to `BENCH_fit_passes.json`.
#[derive(Debug, Clone)]
pub struct FitBenchReport {
    pub tolerance: f64,
    pub searches: Vec<FitSearchReport>,
}

impl FitBenchReport {
    pub fn to_json(&self) -> String {
        let searches: Vec<String> = self
            .searches
            .iter()
            .map(|s| {
                // One JSON object per stream traversal: wall time lives on
                // the batch (the traversal is shared), per-candidate
                // arrival counts on the passes inside it.
                let batches: Vec<String> = s
                    .stats
                    .batches
                    .iter()
                    .map(|b| {
                        let passes: Vec<String> = b
                            .passes
                            .iter()
                            .map(|p| {
                                format!(
                                    "            {{\"candidate\": {}, \"arrivals\": {}, \
                                     \"aborted\": {}, \"feasible\": {}}}",
                                    p.candidate, p.arrivals, p.aborted, p.feasible
                                )
                            })
                            .collect();
                        format!(
                            "        {{\n          \"wall_seconds\": {:.4},\n          \
                             \"stream_arrivals\": {},\n          \
                             \"passes\": [\n{}\n          ]\n        }}",
                            b.wall_seconds,
                            b.stream_arrivals(),
                            passes.join(",\n"),
                        )
                    })
                    .collect();
                format!(
                    "    {{\n      \"scheduler\": \"{}\",\n      \"engine\": \"{}\",\n      \
                     \"fitted\": {},\n      \
                     \"fitted_candidate\": {},\n      \"feasible\": {},\n      \
                     \"total_arrivals\": {},\n      \"wall_seconds\": {:.3},\n      \
                     \"passes_total\": {},\n      \"passes_aborted\": {},\n      \
                     \"full_trace_equivalents\": {:.3},\n      \
                     \"simulated_trace_equivalents\": {:.3},\n      \
                     \"batches\": [\n{}\n      ]\n    }}",
                    s.scheduler,
                    s.stats.engine,
                    s.fitted,
                    s.stats.fitted_candidate,
                    s.stats.feasible,
                    s.stats.total_arrivals,
                    s.wall_seconds,
                    s.stats.pass_count(),
                    s.stats.aborted_passes(),
                    s.stats.full_trace_equivalents(),
                    s.stats.simulated_trace_equivalents(),
                    batches.join(",\n"),
                )
            })
            .collect();
        format!(
            "{{\n  \"tolerance\": {},\n  \"searches\": [\n{}\n  ]\n}}\n",
            self.tolerance,
            searches.join(",\n"),
        )
    }

    /// The CI tripwire, two checks per search:
    ///
    /// 1. **Disarm detector** (exact): every infeasible pass must be
    ///    *aborted* — an infeasible pass with `aborted == false` means
    ///    the miss budget never armed (e.g. a lost `len_hint`) and the
    ///    search is back to streaming full linear passes. The deliberate
    ///    unbounded rerun of the ceiling candidate on a failed search is
    ///    the one exemption.
    /// 2. **Early-abort demonstration**: the *most cheaply refuted*
    ///    aborted pass must have stopped within `max_fraction` of the
    ///    trace. The minimum (not every pass) is the sound gate: a
    ///    marginal candidate just below the fitted one legitimately
    ///    accrues its budget-crossing miss late in the trace, but the
    ///    deeply underprovisioned gallop probes of this bench's workload
    ///    must blow their budget almost immediately — if even the best
    ///    abort streamed most of the trace, the budget is not cutting
    ///    passes short.
    pub fn assert_abort_fraction(&self, max_fraction: f64) -> Result<(), String> {
        for s in &self.searches {
            let total = s.stats.total_arrivals.max(1);
            // Flattened probe order; lockstep batches contribute their
            // candidates in ascending probe order, so the tail exemption
            // below still lands on the ceiling rerun.
            let passes: Vec<_> = s.stats.passes().collect();
            // On ceiling failure the last pass is an intentional
            // unbounded rerun of the infeasible ceiling candidate.
            let exempt_tail = usize::from(!s.stats.feasible);
            let gated = &passes[..passes.len().saturating_sub(exempt_tail)];
            if let Some(p) = gated.iter().find(|p| !p.feasible && !p.aborted) {
                return Err(format!(
                    "fit-abort regression: {} candidate {} was infeasible yet \
                     streamed the trace unaborted ({} of {} arrivals) — the \
                     early-abort budget is disarmed",
                    s.scheduler, p.candidate, p.arrivals, total
                ));
            }
            let min_frac = gated
                .iter()
                .filter(|p| p.aborted)
                .map(|p| p.arrivals as f64 / total as f64)
                .fold(f64::INFINITY, f64::min);
            if min_frac.is_finite() && min_frac > max_fraction {
                return Err(format!(
                    "fit-abort regression: {}'s cheapest aborted pass still \
                     streamed {:.0}% of the trace (cap {:.0}%) — the early-abort \
                     budget is not cutting infeasible passes short",
                    s.scheduler,
                    min_frac * 100.0,
                    max_fraction * 100.0
                ));
            }
        }
        // Vacuity guard: the gate only demonstrates anything if the bench
        // workload actually forced an abort somewhere. If every search fit
        // at its first candidate, nothing above ran and a disarmed budget
        // would be invisible — fail loudly so the bench workload gets
        // retuned to stay underprovisioned at candidate 0.
        if self
            .searches
            .iter()
            .all(|s| s.stats.aborted_passes() == 0)
        {
            return Err(
                "fit-abort tripwire is vacuous: no fitting search produced an \
                 aborted pass — the bench workload no longer exercises the \
                 early-abort path; retune it (it must be infeasible at the \
                 first candidate for at least one search)"
                    .into(),
            );
        }
        Ok(())
    }

    /// The lockstep-economy tripwire: every lockstep-engine search must
    /// have cost at most `max_traversals` full-trace-equivalent stream
    /// traversals. The bench workload fits within the first ladder wave,
    /// so one ladder batch + one bracket batch = ≤ 2 is the expected
    /// shape; a regression toward one traversal per probe (e.g. batching
    /// dismantled back into sequential single-candidate passes) trips
    /// here. The gated metric is per-batch *critical-path* cost
    /// ([`FitBatch::stream_arrivals`] — the max over a batch's
    /// candidates, not their sum), so it is invariant to how a batch
    /// executes: the tee-lockstep plan and the executor's parallel
    /// fresh-stream plan (DESIGN.md §14) score identically. Serial-
    /// engine searches are the comparison baseline and are exempt by
    /// design.
    pub fn assert_fit_passes(&self, max_traversals: f64) -> Result<(), String> {
        let mut checked = 0usize;
        for s in &self.searches {
            if s.stats.engine != "lockstep" {
                continue;
            }
            checked += 1;
            let fte = s.stats.full_trace_equivalents();
            if fte > max_traversals + 1e-9 {
                return Err(format!(
                    "fit-passes regression: {} (lockstep) cost {fte:.2} \
                     full-trace-equivalent stream traversals (cap {max_traversals}) \
                     — candidate batching is no longer sharing the stream",
                    s.scheduler
                ));
            }
        }
        if checked == 0 {
            return Err(
                "fit-passes tripwire is vacuous: no lockstep-engine search in the \
                 report — the fit bench stopped exercising the lockstep engine"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Measure both §5.1 fitting searches, each on both engines (lockstep
/// and serial), over a shared synthetic workload — four searches total,
/// so the JSON shows the traversal economy side by side.
///
/// The workload is deliberately *underprovisioned at low candidates*: a
/// steady stream (b = 0.5) whose initial fleet cannot keep up, so
/// infeasible probes blow their miss budget within the first simulated
/// seconds and both engines have cheap aborted passes to show. The
/// searches stream every pass from the `(seed, 0)` RNG via the same
/// factory the throughput bench uses.
pub fn run_fit_bench(target_arrivals: u64, rate: f64, seed: u64) -> FitBenchReport {
    let duration = target_arrivals as f64 / rate;
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let tolerance = sched::FIT_MISS_TOLERANCE;
    let make = move || -> Box<dyn ArrivalSource> {
        Box::new(synthetic_source(
            "fitbench",
            Rng::for_stream(seed, 0),
            0.5,
            duration,
            rate,
            0.010,
            60.0,
        ))
    };
    let mut searches = Vec::new();
    for engine in [FitEngine::Lockstep, FitEngine::Serial] {
        {
            let t0 = Instant::now();
            let (_, fleet, stats) = sched::fpga_static::fit_source_stats_with(
                engine, &make, &cfg, &defaults, tolerance,
            );
            searches.push(FitSearchReport {
                scheduler: "fpga-static".into(),
                fitted: fleet,
                wall_seconds: t0.elapsed().as_secs_f64(),
                stats,
            });
        }
        {
            let t0 = Instant::now();
            let (_, k, stats) = sched::fpga_dynamic::fit_source_stats_with(
                engine, &make, &cfg, &defaults, tolerance,
            );
            searches.push(FitSearchReport {
                scheduler: "fpga-dynamic".into(),
                fitted: k,
                wall_seconds: t0.elapsed().as_secs_f64(),
                stats,
            });
        }
    }
    FitBenchReport {
        tolerance,
        searches,
    }
}

#[derive(Debug, Clone)]
pub struct BenchSimReport {
    pub scheduler: String,
    /// Arrivals actually replayed (Poisson sampling jitters around the
    /// target).
    pub arrivals: u64,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
    pub arrivals_per_sec: f64,
    /// Peak resident set size in kB (Linux `VmHWM`; 0 where unavailable).
    /// A process-lifetime high-water mark — an upper bound on what the
    /// replay itself needed.
    pub peak_rss_kb: u64,
    pub deadline_misses: u64,
    /// Pool-size scaling axis (empty when not measured).
    pub pool_scaling: Vec<PoolScalePoint>,
}

/// One point of the pool-size scaling axis: a pinned fleet of `workers`
/// serving an arrival stream sized to keep per-worker load constant.
#[derive(Debug, Clone)]
pub struct PoolScalePoint {
    pub workers: u32,
    pub arrivals: u64,
    pub wall_seconds: f64,
    pub arrivals_per_sec: f64,
}

impl PoolScalePoint {
    /// Wall-clock cost per replayed arrival (seconds).
    pub fn per_arrival(&self) -> f64 {
        self.wall_seconds / self.arrivals.max(1) as f64
    }
}

impl BenchSimReport {
    pub fn to_json(&self) -> String {
        let scaling: Vec<String> = self
            .pool_scaling
            .iter()
            .map(|p| {
                format!(
                    "    {{\"workers\": {}, \"arrivals\": {}, \
                     \"wall_seconds\": {:.3}, \"arrivals_per_sec\": {:.1}}}",
                    p.workers, p.arrivals, p.wall_seconds, p.arrivals_per_sec
                )
            })
            .collect();
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"arrivals\": {},\n  \
             \"sim_seconds\": {:.3},\n  \"wall_seconds\": {:.3},\n  \
             \"arrivals_per_sec\": {:.1},\n  \"peak_rss_kb\": {},\n  \
             \"deadline_misses\": {},\n  \"pool_scaling\": [\n{}\n  ]\n}}\n",
            self.scheduler,
            self.arrivals,
            self.sim_seconds,
            self.wall_seconds,
            self.arrivals_per_sec,
            self.peak_rss_kb,
            self.deadline_misses,
            scaling.join(",\n"),
        )
    }
}

/// Peak resident set size (`VmHWM`) in kB. Linux-only proc parse; returns
/// 0 on other platforms (the JSON field then just reads as "unknown").
pub fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// Replay `target_arrivals` synthetic arrivals (rate `rate` req/s,
/// b = 0.65, 10 ms requests) through `kind` on the streaming path and
/// time it end-to-end.
pub fn run_bench_sim(
    kind: &SchedulerKind,
    target_arrivals: u64,
    rate: f64,
    seed: u64,
) -> BenchSimReport {
    let duration = target_arrivals as f64 / rate;
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    // The factory owns only Copy parameters, so it is 'static and
    // re-creatable for however many passes the kind needs.
    let make = move || -> Box<dyn ArrivalSource> {
        Box::new(synthetic_source(
            "bench",
            Rng::for_stream(seed, 0),
            0.65,
            duration,
            rate,
            0.010,
            60.0,
        ))
    };
    // Build (including any fitting/oracle passes) outside the timer so
    // arrivals_per_sec measures exactly one streaming replay for every
    // kind — fitted kinds would otherwise amortize up to 9 untracked
    // passes into the reported throughput.
    let mut policy = sched::build_source(kind, &cfg, &make);
    let t0 = Instant::now();
    let r = sim::run_source(make(), cfg.clone(), &defaults, policy.as_mut());
    let wall = t0.elapsed().as_secs_f64();
    BenchSimReport {
        scheduler: r.scheduler.clone(),
        arrivals: r.metrics.requests,
        sim_seconds: duration,
        wall_seconds: wall,
        arrivals_per_sec: r.metrics.requests as f64 / wall.max(1e-9),
        peak_rss_kb: peak_rss_kb(),
        deadline_misses: r.metrics.deadline_misses,
        pool_scaling: Vec::new(),
    }
}

/// The `spork bench-sim --scenario` axis: one streaming replay under a
/// fault pack, with the planned fault composition (for the Python logic
/// oracle to cross-validate against `tools/scenario_oracle.py`) and the
/// runtime adversity tallies, written to `BENCH_scenario.json`.
#[derive(Debug, Clone)]
pub struct ScenarioBenchReport {
    pub scheduler: String,
    pub scenario: String,
    pub seed_base: u64,
    pub seed: u64,
    pub sim_seconds: f64,
    pub arrivals: u64,
    pub completions: u64,
    pub abandoned: u64,
    pub preemptions: u64,
    pub worker_failures: u64,
    pub redispatches: u64,
    pub work_lost_seconds: f64,
    pub deadline_misses: u64,
    /// Planned (pre-run) fault composition — a pure function of
    /// `(scenario, seed_base, seed, sim_seconds)`.
    pub plan_price_ticks: u64,
    pub plan_preemptions: u64,
    pub plan_failures: u64,
    /// Order-sensitive digest of the full plan (hex), the value the
    /// Python oracle recomputes from scratch.
    pub plan_digest: u64,
    pub wall_seconds: f64,
    pub arrivals_per_sec: f64,
}

impl ScenarioBenchReport {
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"scenario\": \"{}\",\n  \
             \"seed_base\": {},\n  \"seed\": {},\n  \"sim_seconds\": {},\n  \
             \"arrivals\": {},\n  \"completions\": {},\n  \"abandoned\": {},\n  \
             \"preemptions\": {},\n  \"worker_failures\": {},\n  \
             \"redispatches\": {},\n  \"work_lost_seconds\": {:.6},\n  \
             \"deadline_misses\": {},\n  \"plan_price_ticks\": {},\n  \
             \"plan_preemptions\": {},\n  \"plan_failures\": {},\n  \
             \"plan_digest\": \"{:#018x}\",\n  \"wall_seconds\": {:.3},\n  \
             \"arrivals_per_sec\": {:.1}\n}}\n",
            self.scheduler,
            self.scenario,
            self.seed_base,
            self.seed,
            self.sim_seconds,
            self.arrivals,
            self.completions,
            self.abandoned,
            self.preemptions,
            self.worker_failures,
            self.redispatches,
            self.work_lost_seconds,
            self.deadline_misses,
            self.plan_price_ticks,
            self.plan_preemptions,
            self.plan_failures,
            self.plan_digest,
            self.wall_seconds,
            self.arrivals_per_sec,
        )
    }

    /// Arrival conservation: every arrival either completed or was
    /// abandoned. A leak here means kills are dropping in-flight requests
    /// on the floor (or re-dispatch double-counts).
    pub fn assert_conservation(&self) -> Result<(), String> {
        if self.arrivals != self.completions + self.abandoned {
            return Err(format!(
                "scenario conservation violated: {} arrivals != {} completions \
                 + {} abandoned — kills are leaking in-flight requests",
                self.arrivals, self.completions, self.abandoned
            ));
        }
        Ok(())
    }

    /// Vacuity tripwire for adverse packs: a severe run that injects zero
    /// preemptions/failures is measuring nothing — fail loudly so the
    /// pack (or the smoke window) gets retuned.
    pub fn assert_adversity(&self) -> Result<(), String> {
        if self.preemptions + self.worker_failures == 0 {
            return Err(format!(
                "scenario tripwire is vacuous: pack '{}' injected no preemptions \
                 or failures over {:.0}s ({} planned strikes, {} planned \
                 failures) — retune the pack or widen the window",
                self.scenario, self.sim_seconds, self.plan_preemptions, self.plan_failures
            ));
        }
        Ok(())
    }
}

/// Replay `target_arrivals` synthetic arrivals through `kind` under
/// `scenario` (same workload shape as [`run_bench_sim`]); fitting stays
/// fault-free and outside the timer.
pub fn run_bench_sim_scenario(
    kind: &SchedulerKind,
    target_arrivals: u64,
    rate: f64,
    seed: u64,
    scenario: &ScenarioConfig,
) -> ScenarioBenchReport {
    let duration = target_arrivals as f64 / rate;
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let make = move || -> Box<dyn ArrivalSource> {
        Box::new(synthetic_source(
            "bench",
            Rng::for_stream(seed, 0),
            0.65,
            duration,
            rate,
            0.010,
            60.0,
        ))
    };
    let mut policy = sched::build_source(kind, &cfg, &make);
    // The driver derives the identical plan internally (pure function);
    // this copy only feeds the report's planned-composition fields.
    let plan = FaultPlan::build(scenario, seed, 0, duration);
    let counts = plan.counts();
    let t0 = Instant::now();
    let r = sim::run_source_scenario(
        make(),
        cfg.clone(),
        &defaults,
        policy.as_mut(),
        scenario,
        seed,
        0,
    );
    let wall = t0.elapsed().as_secs_f64();
    let m = &r.metrics;
    ScenarioBenchReport {
        scheduler: r.scheduler.clone(),
        scenario: scenario.name.clone(),
        seed_base: seed,
        seed: 0,
        sim_seconds: duration,
        arrivals: m.requests,
        completions: m.completions,
        abandoned: m.abandoned,
        preemptions: m.preemptions,
        worker_failures: m.worker_failures,
        redispatches: m.redispatches,
        work_lost_seconds: m.work_lost,
        deadline_misses: m.deadline_misses,
        plan_price_ticks: counts.price_ticks,
        plan_preemptions: counts.preemptions,
        plan_failures: counts.failures,
        plan_digest: plan.digest(),
        wall_seconds: wall,
        arrivals_per_sec: m.requests as f64 / wall.max(1e-9),
    }
}

/// A statically provisioned fleet that exists only to measure dispatch:
/// pre-warms `cpus + fpgas` workers at t = 0, keeps them alive while the
/// trace is live, and routes every arrival through [`Dispatcher::find`]
/// over the full fleet — so per-arrival cost is dominated by exactly the
/// term the pool-scaling axis tracks.
struct PinnedFleet {
    cpus: u32,
    fpgas: u32,
    dispatcher: Dispatcher,
}

impl PinnedFleet {
    fn new(cpus: u32, fpgas: u32) -> Self {
        Self {
            cpus,
            fpgas,
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

impl Policy for PinnedFleet {
    fn name(&self) -> String {
        "pinned-fleet".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &WorkerKind::EFFICIENT_FIRST;
        match obs {
            Observation::Start => {
                out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: self.fpgas,
                    prewarmed: true,
                });
                out.push(Action::Alloc {
                    kind: WorkerKind::Cpu,
                    n: self.cpus,
                    prewarmed: true,
                });
            }
            Observation::Arrival { req } => {
                let to = match self.dispatcher.find(view, &req, KINDS) {
                    Some(w) => Target::Worker(w),
                    // Caps equal the fleet, so this falls back to the
                    // earliest-finishing worker instead of growing.
                    None => Target::Fresh(WorkerKind::Cpu),
                };
                out.push(Action::Dispatch { req, to });
            }
            Observation::IdleExpired { worker } => {
                if view.trace_live() {
                    out.push(Action::KeepAlive { worker });
                }
            }
            _ => {}
        }
    }
}

/// Replay `arrivals_each` arrivals against a pinned fleet of each size in
/// `sizes` (per-worker load held constant at ~20 req/s of 10 ms work, so
/// only the fleet dimension varies) and time the replays. Idle timeouts
/// are pinned to the replay window so event traffic doesn't scale with
/// fleet size — the measured axis is dispatch cost.
pub fn run_pool_scaling(sizes: &[u32], arrivals_each: u64, seed: u64) -> Vec<PoolScalePoint> {
    let defaults = PlatformConfig::paper_default();
    let mut points = Vec::new();
    for &workers in sizes {
        let fpgas = (workers / 2).max(1);
        let cpus = (workers - fpgas).max(1);
        let rate = workers as f64 * 20.0;
        let duration = arrivals_each as f64 / rate;
        let mut cfg = SimConfig::paper_default();
        cfg.max_fpgas = Some(fpgas);
        cfg.max_cpus = Some(cpus);
        // One idle-expiry consult per worker after the window, not a
        // per-5ms KeepAlive storm across a 10k-CPU fleet.
        cfg.cpu_idle_timeout = duration.max(1.0);
        cfg.fpga_idle_timeout = duration.max(1.0);
        let source = synthetic_source(
            "scale",
            Rng::for_stream(seed, workers as u64),
            0.65,
            duration,
            rate,
            0.010,
            60.0,
        );
        let mut policy = PinnedFleet::new(cpus, fpgas);
        let t0 = Instant::now();
        let r = sim::run_source(Box::new(source), cfg, &defaults, &mut policy);
        let wall = t0.elapsed().as_secs_f64();
        points.push(PoolScalePoint {
            workers,
            arrivals: r.metrics.requests,
            wall_seconds: wall,
            arrivals_per_sec: r.metrics.requests as f64 / wall.max(1e-9),
        });
    }
    points
}

/// One timing point of the `--par-apps` axis: the same production cell
/// run with a specific per-call worker cap (`0` = the executor's full
/// budget).
pub struct ParAppsPoint {
    pub jobs: usize,
    pub wall_seconds: f64,
}

/// The `--par-apps` axis report (`BENCH_par_apps.json`): one multi-app
/// production cell timed at `--jobs` 1 / 2 / 0. The runner asserts the
/// three cells bit-identical before any timing is reported, so the axis
/// is a perf probe wrapped around a parity tripwire.
pub struct ParAppsBenchReport {
    pub scheduler: String,
    pub apps: usize,
    pub arrivals: u64,
    pub points: Vec<ParAppsPoint>,
}

impl ParAppsBenchReport {
    pub fn to_json(&self) -> String {
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "    {{\"jobs\": {}, \"wall_seconds\": {:.4}}}",
                    p.jobs, p.wall_seconds
                )
            })
            .collect();
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"apps\": {},\n  \"arrivals\": {},\n  \
             \"points\": [\n{}\n  ]\n}}\n",
            self.scheduler,
            self.apps,
            self.arrivals,
            points.join(",\n")
        )
    }

    /// CI tripwire: the parallel run (jobs = 0) must not be more than
    /// `cap`× slower than the forced-serial run (jobs = 1). This gates
    /// *overhead*, not speedup — CI runners may expose two cores, where
    /// the win is small, but a parallel path that is materially slower
    /// than serial means the executor regressed into contention or
    /// oversubscription. The guard errors rather than passing vacuously
    /// when the bench produced no apps or lacks either reference point.
    pub fn assert_par_overhead(&self, cap: f64) -> Result<(), String> {
        if self.apps == 0 || self.arrivals == 0 {
            return Err(
                "par-apps overhead tripwire is vacuous: the bench workload generated \
                 no apps/arrivals — retune --par-apps-count or the workload scale"
                    .into(),
            );
        }
        let wall_of = |jobs: usize| {
            self.points
                .iter()
                .find(|p| p.jobs == jobs)
                .map(|p| p.wall_seconds)
        };
        let serial = wall_of(1).ok_or(
            "par-apps overhead tripwire is vacuous: no jobs=1 (serial reference) point",
        )?;
        let auto = wall_of(0).ok_or(
            "par-apps overhead tripwire is vacuous: no jobs=0 (full budget) point",
        )?;
        // Tiny absolute slack so near-zero walls can't trip on noise.
        if auto > serial * cap + 1e-3 {
            return Err(format!(
                "per-app parallelism overhead regression: the jobs=0 production cell \
                 took {auto:.3}s vs {serial:.3}s serial ({:.2}x, cap {cap}x) — the \
                 executor fan-out now costs more than the serial loop it replaced",
                auto / serial.max(1e-9)
            ));
        }
        Ok(())
    }
}

/// Generate one `app_count`-app production workload and run it through
/// [`run_production_jobs`] at jobs 1 (forced serial), 2, and 0 (full
/// executor budget), timing each. Errors — rather than reporting
/// timings — if the three cells are not bit-identical, since a parallel
/// cell that diverges from serial is wrong no matter how fast it is.
pub fn run_par_apps_bench(app_count: usize, seed: u64) -> Result<ParAppsBenchReport, String> {
    let params = ProductionParams {
        dataset: Dataset::AzureFunctions,
        bucket: SizeBucket::Short,
        duration: 600.0,
        scale: 0.05,
        max_apps: Some(app_count),
    };
    let mut rng = Rng::new(seed);
    let apps = production::generate(&params, &mut rng);
    let arrivals: u64 = apps.iter().map(|a| a.arrivals.len() as u64).sum();
    let cfg = SimConfig::paper_default();
    let kind = SchedulerKind::spork_e();
    let mut points = Vec::new();
    let mut cells = Vec::new();
    for jobs in [1usize, 2, 0] {
        let t0 = Instant::now();
        let cell = run_production_jobs(&kind, &cfg, &apps, jobs);
        points.push(ParAppsPoint {
            jobs,
            wall_seconds: t0.elapsed().as_secs_f64(),
        });
        cells.push((jobs, cell));
    }
    let (_, reference) = &cells[0];
    for (jobs, cell) in &cells[1..] {
        if cell != reference {
            return Err(format!(
                "par-apps parity violation: the production cell at --jobs {jobs} \
                 diverged from the serial reference — the per-app parallel merge \
                 is no longer bit-identical (DESIGN.md §14)"
            ));
        }
    }
    Ok(ParAppsBenchReport {
        scheduler: kind.name(),
        apps: apps.len(),
        arrivals,
        points,
    })
}

/// `spork bench-sim` CLI entrypoint.
pub fn cmd_bench_sim(args: &Args) -> Result<(), String> {
    let arrivals = args.u64_or("arrivals", 1_000_000)?;
    let rate = args.f64_or("rate", 2000.0)?;
    if arrivals == 0 {
        return Err("--arrivals must be > 0".into());
    }
    if !(rate > 0.0 && rate.is_finite()) {
        return Err("--rate must be a finite positive number".into());
    }
    let seed = args.u64_or("seed", 1)?;
    // Seed the process-wide executor budget before any axis runs; the
    // par-apps axis (and anything else fanning out) draws from it.
    let jobs = args.usize_or("jobs", 0)?;
    Executor::configure(jobs);
    let out = args.str_or("out", "BENCH_sim_throughput.json");
    let name = args.str_or("scheduler", "spork-e");
    let kind = SchedulerKind::from_name(&name)
        .ok_or(format!("unknown scheduler '{name}'"))?;
    let sizes = parse_pool_sizes(&args.str_or("pool-sizes", "100,1000,10000"))?;
    let scaling_arrivals = args.u64_or("scaling-arrivals", 200_000)?;
    let assert_scaling = match args.get("assert-scaling") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-scaling: invalid ratio '{v}'"))?,
        ),
        None => None,
    };
    let fit = args.has_flag("fit");
    let fit_arrivals = args.u64_or("fit-arrivals", 200_000)?;
    let fit_out = args.str_or("fit-out", "BENCH_fit_passes.json");
    let assert_fit_abort = match args.get("assert-fit-abort") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-fit-abort: invalid fraction '{v}'"))?,
        ),
        None => None,
    };
    if assert_fit_abort.is_some() && !fit {
        return Err("--assert-fit-abort requires --fit".into());
    }
    let assert_fit_passes = match args.get("assert-fit-passes") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-fit-passes: invalid traversal cap '{v}'"))?,
        ),
        None => None,
    };
    if assert_fit_passes.is_some() && !fit {
        return Err("--assert-fit-passes requires --fit".into());
    }
    let par_apps = args.has_flag("par-apps");
    let par_apps_count = args.usize_or("par-apps-count", 8)?;
    let par_apps_out = args.str_or("par-apps-out", "BENCH_par_apps.json");
    let assert_par_overhead = match args.get("assert-par-overhead") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-par-overhead: invalid ratio '{v}'"))?,
        ),
        None => None,
    };
    if assert_par_overhead.is_some() && !par_apps {
        return Err("--assert-par-overhead requires --par-apps".into());
    }
    let scenario = match args.get("scenario") {
        Some(name) => Some(
            ScenarioConfig::from_name(&name)
                .ok_or(format!("unknown scenario pack '{name}' (fault-free|mild|severe)"))?,
        ),
        None => None,
    };
    let scenario_out = args.str_or("scenario-out", "BENCH_scenario.json");
    let scenario_arrivals = args.u64_or("scenario-arrivals", arrivals.min(200_000))?;
    eprintln!(
        "replaying ~{arrivals} arrivals at {rate} req/s through {} (streaming)...",
        kind.display()
    );
    let mut report = run_bench_sim(&kind, arrivals, rate, seed);
    if !sizes.is_empty() && scaling_arrivals > 0 {
        eprintln!(
            "pool-scaling axis: ~{scaling_arrivals} arrivals per fleet size {sizes:?}..."
        );
        report.pool_scaling = run_pool_scaling(&sizes, scaling_arrivals, seed);
    }
    let json = report.to_json();
    std::fs::write(&out, &json).map_err(|e| format!("writing {out}: {e}"))?;
    println!(
        "{} arrivals in {:.2}s = {:.0} arrivals/s (peak RSS {} kB, {} misses) -> {}",
        report.arrivals,
        report.wall_seconds,
        report.arrivals_per_sec,
        report.peak_rss_kb,
        report.deadline_misses,
        out
    );
    for p in &report.pool_scaling {
        println!(
            "  pool {:>6} workers: {} arrivals in {:.2}s = {:.0} arrivals/s",
            p.workers, p.arrivals, p.wall_seconds, p.arrivals_per_sec
        );
    }
    if let Some(cap) = assert_scaling {
        let (small, large) = match (report.pool_scaling.first(), report.pool_scaling.last()) {
            (Some(s), Some(l)) if s.workers < l.workers => (s, l),
            _ => return Err("--assert-scaling needs >= 2 ascending --pool-sizes".into()),
        };
        let ratio = large.per_arrival() / small.per_arrival().max(1e-12);
        println!(
            "  per-arrival cost growth {}->{} workers: {ratio:.2}x (cap {cap}x)",
            small.workers, large.workers
        );
        if ratio > cap {
            return Err(format!(
                "dispatch cost scaling regression: per-arrival cost grew {ratio:.2}x \
                 from {} to {} workers (cap {cap}x) — an O(fleet) scan is back on \
                 the arrival hot path",
                small.workers, large.workers
            ));
        }
    }
    if fit {
        eprintln!(
            "fit axis: ~{fit_arrivals} arrivals through both §5.1 fitting searches..."
        );
        let fit_report = run_fit_bench(fit_arrivals, rate, seed);
        std::fs::write(&fit_out, fit_report.to_json())
            .map_err(|e| format!("writing {fit_out}: {e}"))?;
        for s in &fit_report.searches {
            println!(
                "  fit {:<14} [{:>8}] fitted {:>5} in {} passes / {} batches \
                 ({} aborted early, {:.2} stream / {:.2} simulated full-trace \
                 equivalents) {:.2}s -> {}",
                s.scheduler,
                s.stats.engine,
                s.fitted,
                s.stats.pass_count(),
                s.stats.batches.len(),
                s.stats.aborted_passes(),
                s.stats.full_trace_equivalents(),
                s.stats.simulated_trace_equivalents(),
                s.wall_seconds,
                fit_out
            );
        }
        if let Some(frac) = assert_fit_abort {
            fit_report.assert_abort_fraction(frac)?;
            println!(
                "  fit abort tripwire: all aborted passes streamed <= {:.0}% of the trace",
                frac * 100.0
            );
        }
        if let Some(cap) = assert_fit_passes {
            fit_report.assert_fit_passes(cap)?;
            println!(
                "  fit passes tripwire: every lockstep search cost <= {cap} \
                 full-trace-equivalent stream traversals"
            );
        }
    }
    if par_apps {
        eprintln!(
            "par-apps axis: {par_apps_count}-app production cell at --jobs 1 / 2 / 0..."
        );
        let pr = run_par_apps_bench(par_apps_count, seed)?;
        std::fs::write(&par_apps_out, pr.to_json())
            .map_err(|e| format!("writing {par_apps_out}: {e}"))?;
        for p in &pr.points {
            let label = if p.jobs == 0 {
                "auto".to_string()
            } else {
                p.jobs.to_string()
            };
            println!(
                "  par-apps jobs {label:>4}: {} apps / {} arrivals in {:.2}s -> {}",
                pr.apps, pr.arrivals, p.wall_seconds, par_apps_out
            );
        }
        println!("  par-apps parity: cells bit-identical across jobs 1/2/0");
        if let Some(cap) = assert_par_overhead {
            pr.assert_par_overhead(cap)?;
            println!(
                "  par-apps tripwire: parallel (jobs=0) within {cap}x of the serial wall"
            );
        }
    }
    if let Some(scen) = scenario {
        eprintln!(
            "scenario axis: ~{scenario_arrivals} arrivals through {} under pack '{}'...",
            kind.display(),
            scen.name
        );
        let s = run_bench_sim_scenario(&kind, scenario_arrivals, rate, seed, &scen);
        std::fs::write(&scenario_out, s.to_json())
            .map_err(|e| format!("writing {scenario_out}: {e}"))?;
        println!(
            "  scenario '{}': {} arrivals = {} completed + {} abandoned; \
             {} preemptions, {} failures, {} re-dispatches, {:.2}s work lost \
             (plan: {} strikes / {} failures / {} ticks, digest {:#018x}) -> {}",
            s.scenario,
            s.arrivals,
            s.completions,
            s.abandoned,
            s.preemptions,
            s.worker_failures,
            s.redispatches,
            s.work_lost_seconds,
            s.plan_preemptions,
            s.plan_failures,
            s.plan_price_ticks,
            s.plan_digest,
            scenario_out
        );
        // Conservation always holds; adversity only gates adverse packs
        // (fault-free is legitimately quiet).
        s.assert_conservation()?;
        if scen.is_adverse() {
            s.assert_adversity()?;
            println!("  scenario tripwire: pack injected real adversity (non-vacuous)");
        }
    }
    Ok(())
}

/// Parse a `--pool-sizes` comma list ("100,1000,10000").
fn parse_pool_sizes(spec: &str) -> Result<Vec<u32>, String> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Ok(Vec::new());
    }
    spec.split(',')
        .map(|t| {
            let t = t.trim();
            match t.parse::<u32>() {
                Ok(n) if n > 0 => Ok(n),
                _ => Err(format!("--pool-sizes: invalid fleet size '{t}'")),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{FitBatch, FitPass};

    #[test]
    fn small_bench_runs_and_reports() {
        let r = run_bench_sim(&SchedulerKind::spork_e(), 5_000, 500.0, 7);
        assert_eq!(r.scheduler, "spork-e");
        // Poisson jitter: within 20% of target.
        assert!(
            (r.arrivals as f64 - 5_000.0).abs() < 1_000.0,
            "arrivals {}",
            r.arrivals
        );
        assert!(r.arrivals_per_sec > 0.0);
        let j = r.to_json();
        assert!(j.contains("\"arrivals_per_sec\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run_bench_sim(&SchedulerKind::spork_e(), 2_000, 400.0, 3);
        let b = run_bench_sim(&SchedulerKind::spork_e(), 2_000, 400.0, 3);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.deadline_misses, b.deadline_misses);
    }

    #[test]
    fn pool_scaling_replays_every_size_and_serializes() {
        let points = run_pool_scaling(&[8, 32], 1_500, 11);
        assert_eq!(points.len(), 2);
        for p in &points {
            // Poisson jitter around the per-size target.
            assert!(
                (p.arrivals as f64 - 1_500.0).abs() < 600.0,
                "arrivals {} at {} workers",
                p.arrivals,
                p.workers
            );
            assert!(p.arrivals_per_sec > 0.0);
        }
        let mut r = run_bench_sim(&SchedulerKind::spork_e(), 1_000, 400.0, 3);
        r.pool_scaling = points;
        let j = r.to_json();
        assert!(j.contains("\"pool_scaling\""));
        assert!(j.contains("\"workers\": 32"));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn par_apps_bench_holds_parity_and_serializes() {
        // Small population: the runner itself errors on any cross-jobs
        // divergence, so an Ok here IS the parity assertion.
        let r = run_par_apps_bench(3, 21).expect("parallel production cell must match serial");
        assert_eq!(r.points.len(), 3);
        assert_eq!(r.points[0].jobs, 1);
        assert_eq!(r.points.last().unwrap().jobs, 0);
        assert!(r.apps > 0 && r.arrivals > 0, "bench workload came up empty");
        // A generous cap: the unit test only checks the plumbing; CI owns
        // the real 1.2x gate where walls are long enough to be stable.
        assert!(r.assert_par_overhead(1000.0).is_ok());
        let j = r.to_json();
        assert!(j.contains("\"points\""));
        assert!(j.contains("\"jobs\": 0"));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "par-apps JSON must parse");
    }

    #[test]
    fn par_apps_tripwire_flags_overhead_and_vacuity() {
        let report = |serial: f64, auto: f64, apps: usize| ParAppsBenchReport {
            scheduler: "spork-e".into(),
            apps,
            arrivals: if apps == 0 { 0 } else { 1_000 },
            points: vec![
                ParAppsPoint {
                    jobs: 1,
                    wall_seconds: serial,
                },
                ParAppsPoint {
                    jobs: 2,
                    wall_seconds: (serial + auto) / 2.0,
                },
                ParAppsPoint {
                    jobs: 0,
                    wall_seconds: auto,
                },
            ],
        };
        assert!(report(1.0, 1.1, 4).assert_par_overhead(1.2).is_ok());
        let err = report(1.0, 1.5, 4).assert_par_overhead(1.2).unwrap_err();
        assert!(err.contains("overhead regression"), "unexpected error: {err}");
        // An empty app population must error, not pass vacuously.
        let err = report(1.0, 1.0, 0).assert_par_overhead(1.2).unwrap_err();
        assert!(err.contains("vacuous"), "unexpected error: {err}");
        // So must a report missing either reference point.
        let mut missing = report(1.0, 1.0, 4);
        missing.points.retain(|p| p.jobs != 0);
        assert!(missing.assert_par_overhead(1.2).is_err());
        let mut missing = report(1.0, 1.0, 4);
        missing.points.retain(|p| p.jobs != 1);
        assert!(missing.assert_par_overhead(1.2).is_err());
    }

    #[test]
    fn fit_bench_reports_and_serializes() {
        let r = run_fit_bench(15_000, 1500.0, 5);
        // Two schedulers × two engines.
        assert_eq!(r.searches.len(), 4);
        for s in &r.searches {
            assert!(s.stats.pass_count() >= 1, "{} ran no passes", s.scheduler);
            assert!(s.stats.total_arrivals > 0);
            assert!(s.stats.feasible, "{} bench workload must be fittable", s.scheduler);
            // The winning pass is always full-trace.
            let last_full = s.stats.passes().filter(|p| !p.aborted).last().unwrap();
            assert_eq!(last_full.arrivals, s.stats.total_arrivals);
        }
        // The two engines must agree on the fitted value per scheduler —
        // the bench doubles as a coarse cross-engine parity check.
        for sched_name in ["fpga-static", "fpga-dynamic"] {
            let fitted: Vec<u32> = r
                .searches
                .iter()
                .filter(|s| s.scheduler == sched_name)
                .map(|s| s.fitted)
                .collect();
            assert_eq!(fitted.len(), 2);
            assert_eq!(fitted[0], fitted[1], "{sched_name}: engines disagree");
        }
        // Lockstep economy on the bench workload: a fit inside the first
        // ladder wave takes one ladder batch + at most one bracket batch.
        for s in r.searches.iter().filter(|s| s.stats.engine == "lockstep") {
            if s.stats.fitted_candidate <= 16 {
                assert!(
                    s.stats.full_trace_equivalents() <= 2.0 + 1e-9,
                    "{}: {} traversals",
                    s.scheduler,
                    s.stats.full_trace_equivalents()
                );
            }
        }
        assert!(r.assert_fit_passes(2.0).is_ok());
        let j = r.to_json();
        assert!(j.contains("\"full_trace_equivalents\""));
        assert!(j.contains("\"simulated_trace_equivalents\""));
        assert!(j.contains("\"engine\": \"lockstep\""));
        assert!(j.contains("\"engine\": \"serial\""));
        assert!(j.contains("\"batches\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "fit JSON must parse");
    }

    fn one_pass_batch(p: FitPass) -> FitBatch {
        FitBatch {
            passes: vec![p],
            wall_seconds: 0.0,
        }
    }

    #[test]
    fn fit_abort_tripwire_flags_late_aborts() {
        let pass = |arrivals: u64, aborted: bool| FitPass {
            candidate: 0,
            arrivals,
            aborted,
            feasible: !aborted,
        };
        let report = |abort_at: u64| FitBenchReport {
            tolerance: 0.005,
            searches: vec![FitSearchReport {
                scheduler: "fpga-static".into(),
                fitted: 1,
                wall_seconds: 0.0,
                stats: FitStats {
                    label: "fpga-static".into(),
                    engine: "serial",
                    fitted_candidate: 1,
                    feasible: true,
                    total_arrivals: 1000,
                    batches: vec![
                        one_pass_batch(pass(abort_at, true)),
                        one_pass_batch(pass(1000, false)),
                    ],
                },
            }],
        };
        assert!(report(100).assert_abort_fraction(0.5).is_ok());
        assert!(report(900).assert_abort_fraction(0.5).is_err());
    }

    #[test]
    fn fit_abort_tripwire_catches_disarmed_abort() {
        // A full-length pass that is *infeasible but not aborted* is the
        // signature of a silently disarmed early-abort budget (e.g. a
        // lost len_hint) — the tripwire must not pass vacuously.
        let disarmed = FitBenchReport {
            tolerance: 0.005,
            searches: vec![FitSearchReport {
                scheduler: "fpga-dynamic".into(),
                fitted: 1,
                wall_seconds: 0.0,
                stats: FitStats {
                    label: "fpga-dynamic".into(),
                    engine: "lockstep",
                    fitted_candidate: 1,
                    feasible: true,
                    total_arrivals: 1000,
                    // One lockstep batch probing both candidates: the
                    // infeasible one streamed the whole trace unaborted.
                    batches: vec![FitBatch {
                        passes: vec![
                            FitPass {
                                candidate: 0,
                                arrivals: 1000, // full trace, never aborted
                                aborted: false,
                                feasible: false,
                            },
                            FitPass {
                                candidate: 1,
                                arrivals: 1000,
                                aborted: false,
                                feasible: true,
                            },
                        ],
                        wall_seconds: 0.0,
                    }],
                },
            }],
        };
        assert!(disarmed.assert_abort_fraction(0.5).is_err());
        // The deliberate unbounded rerun of a failed (ceiling) search is
        // exempt — it is the only pass allowed to be infeasible AND full.
        let mut failed = disarmed.clone();
        failed.searches[0].stats.feasible = false;
        failed.searches[0].stats.batches = vec![
            one_pass_batch(FitPass {
                candidate: 4096,
                arrivals: 80,
                aborted: true,
                feasible: false,
            }),
            one_pass_batch(FitPass {
                candidate: 4096,
                arrivals: 1000,
                aborted: false,
                feasible: false,
            }),
        ];
        assert!(failed.assert_abort_fraction(0.5).is_ok());
        // All-feasible searches make the gate vacuous — that must fail
        // too (the bench workload is supposed to force aborts).
        let mut vacuous = disarmed.clone();
        vacuous.searches[0].stats.fitted_candidate = 0;
        vacuous.searches[0].stats.batches = vec![one_pass_batch(FitPass {
            candidate: 0,
            arrivals: 1000,
            aborted: false,
            feasible: true,
        })];
        let err = vacuous.assert_abort_fraction(0.5).unwrap_err();
        assert!(err.contains("vacuous"), "unexpected error: {err}");
    }

    #[test]
    fn fit_passes_tripwire_caps_lockstep_traversals() {
        let full_pass = |candidate: u32| FitPass {
            candidate,
            arrivals: 1000,
            aborted: false,
            feasible: true,
        };
        let search = |engine: &'static str, batches: Vec<FitBatch>| FitSearchReport {
            scheduler: "fpga-static".into(),
            fitted: 1,
            wall_seconds: 0.0,
            stats: FitStats {
                label: "fpga-static".into(),
                engine,
                fitted_candidate: 1,
                feasible: true,
                total_arrivals: 1000,
                batches,
            },
        };
        // Ladder batch (abort prefix) + full bracket batch = 1.1 traversals.
        let good = FitBenchReport {
            tolerance: 0.005,
            searches: vec![search(
                "lockstep",
                vec![
                    FitBatch {
                        passes: vec![
                            FitPass {
                                candidate: 0,
                                arrivals: 100,
                                aborted: true,
                                feasible: false,
                            },
                            full_pass(1),
                        ],
                        wall_seconds: 0.0,
                    },
                    one_pass_batch(full_pass(1)),
                ],
            )],
        };
        assert!(good.assert_fit_passes(2.0).is_ok());
        // One full traversal per probe — the regression the cap exists for.
        let bad = FitBenchReport {
            tolerance: 0.005,
            searches: vec![search(
                "lockstep",
                (0..3).map(|c| one_pass_batch(full_pass(c))).collect(),
            )],
        };
        let err = bad.assert_fit_passes(2.0).unwrap_err();
        assert!(err.contains("fit-passes regression"), "unexpected error: {err}");
        // Serial searches are exempt — but a report with *only* serial
        // searches means the lockstep engine is no longer measured.
        let serial_only = FitBenchReport {
            tolerance: 0.005,
            searches: vec![search(
                "serial",
                (0..9).map(|c| one_pass_batch(full_pass(c))).collect(),
            )],
        };
        let err = serial_only.assert_fit_passes(2.0).unwrap_err();
        assert!(err.contains("vacuous"), "unexpected error: {err}");
    }

    #[test]
    fn scenario_bench_severe_is_nonvacuous_and_conserves() {
        // 30k arrivals at 500/s = a 60 s window: Spork allocates its first
        // FPGAs at the t=10 interval tick, and the severe pack's strikes
        // after that (t=13.3 on at this seed) land on live victims — a
        // shorter window would strike before any FPGA exists.
        let s = run_bench_sim_scenario(
            &SchedulerKind::spork_e(),
            30_000,
            500.0,
            7,
            &ScenarioConfig::severe(),
        );
        assert!(s.assert_conservation().is_ok());
        assert!(
            s.assert_adversity().is_ok(),
            "severe smoke injected nothing: plan {} strikes / {} failures",
            s.plan_preemptions,
            s.plan_failures
        );
        assert_eq!(s.scenario, "severe");
        let j = s.to_json();
        assert!(j.contains("\"plan_digest\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "scenario JSON must parse");
    }

    #[test]
    fn scenario_bench_fault_free_matches_plain_bench() {
        let plain = run_bench_sim(&SchedulerKind::spork_e(), 3_000, 500.0, 9);
        let s = run_bench_sim_scenario(
            &SchedulerKind::spork_e(),
            3_000,
            500.0,
            9,
            &ScenarioConfig::fault_free(),
        );
        assert_eq!(s.arrivals, plain.arrivals);
        assert_eq!(s.deadline_misses, plain.deadline_misses);
        assert_eq!(s.preemptions + s.worker_failures + s.abandoned, 0);
        assert_eq!(s.plan_digest, 0);
        assert!(s.assert_conservation().is_ok());
        // A fault-free pack claiming adversity would be a lie; the
        // tripwire is only armed for adverse packs.
        assert!(s.assert_adversity().is_err());
    }

    #[test]
    fn pool_sizes_parse() {
        assert_eq!(parse_pool_sizes("100, 1000,10000").unwrap(), vec![100, 1000, 10000]);
        assert_eq!(parse_pool_sizes("").unwrap(), Vec::<u32>::new());
        assert!(parse_pool_sizes("12,oops").is_err());
        assert!(parse_pool_sizes("0").is_err());
    }
}
