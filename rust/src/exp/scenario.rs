//! Scenario experiment: the scheduler roster under preemptible spot
//! workers and fault injection (DESIGN.md §12).
//!
//! One row per (fault pack × scheduler): the fault-free pack pins the
//! no-adversity baseline (bit-identical to the plain path), mild models a
//! well-behaved spot market, severe a volatile one with short MTTFs. All
//! cells share workload synthesis through the sweep engine; each seed
//! replicate derives its own fault plan from `(seed_base, seed)`, so the
//! whole grid is bit-identical for every `--jobs` value.

use super::common::ExpCtx;
use super::sweep::{SweepCell, SweepGrid, WorkloadSpec};
use crate::config::{SchedulerKind, SimConfig};
use crate::scenario::ScenarioConfig;
use crate::util::table::{pct, ratio, sig3, Table};

/// Seed root of the scenario grid (distinct from every other experiment
/// so no workload stream is shared across experiments by accident).
const SEED_BASE: u64 = 81;

/// The scenario table: fault packs × the spot-aware scheduler roster.
pub fn scenario(ctx: &ExpCtx) -> Vec<Table> {
    let cfg = SimConfig::paper_default();
    let roster = SchedulerKind::scenario_roster();
    let packs = ScenarioConfig::packs();
    let mut grid = SweepGrid::from_ctx(ctx);
    for pack in &packs {
        for kind in &roster {
            grid.push(SweepCell {
                scheduler: kind.clone(),
                cfg: cfg.clone(),
                workload: WorkloadSpec {
                    burstiness: 0.65,
                    rate: ctx.synthetic_rate(),
                    size: 0.010,
                    duration: ctx.synthetic_duration(),
                },
                seed_base: SEED_BASE,
                scenario: Some(pack.clone()),
            });
        }
    }
    let cells = grid.run();

    let mut t = Table::new(
        "Scenario: schedulers under spot preemption and worker failure \
         (b=0.65; per-seed fault plans)",
        &[
            "pack",
            "Scheduler",
            "Energy Eff.",
            "Rel. Cost",
            "Miss %",
            "Preempt",
            "Fail",
            "Redisp",
            "Abandon",
            "Work lost (s)",
        ],
    );
    let mut it = cells.iter();
    for pack in &packs {
        for kind in &roster {
            let c = it.next().expect("grid/table mismatch");
            t.row(vec![
                pack.name.clone(),
                kind.display(),
                pct(c.energy_eff),
                ratio(c.rel_cost),
                pct(c.miss_frac),
                sig3(c.preemptions),
                sig3(c.worker_failures),
                sig3(c.redispatches),
                sig3(c.abandoned),
                sig3(c.work_lost),
            ]);
        }
    }
    vec![t]
}
