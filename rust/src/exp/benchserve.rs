//! `spork bench-serve`: the serve-path line-rate harness.
//!
//! Replays a production-style workload (the Table 7 generator) through
//! the **sharded real-time router** (`serve::run_serve_sharded`,
//! [`Compute::Paced`]: full pacing loop, no PJRT) at one or more
//! time-scale compressions and reports, per scale: requests served,
//! requests/second of wall time, shed count and fraction, worst replay
//! lag, and latency percentiles to p999 — to `BENCH_serve.json`,
//! mirroring `bench-sim`'s role for the simulator.
//!
//! The CI tripwires are `--assert-max-lag L` (the router must never wake
//! more than `L` wall seconds behind its absolute pacing deadline — the
//! batched-admission and drift-free-pacing guarantees, measured) and
//! `--assert-shed F` (in an unsaturated configuration an *armed* queue
//! cap must shed at most fraction `F`; `--assert-shed 0` with a nonzero
//! `--queue-cap` proves backpressure stays quiet exactly when it should).
//!
//! Every model input is a pure function of `(params, seed, app index)`
//! — each run regenerates its sources from scratch, so points at
//! different time scales serve bit-identical workloads and any request
//! count disagreement across scales is a pacing bug, not noise.
//!
//! `--chaos <pack>` adds the resilience axis: one extra replay of the
//! named fault pack (DESIGN.md §15) at the highest compression, reported
//! to `BENCH_serve_chaos.json` with the plan digest and planned/applied
//! fault counts. Its tripwires are the extended conservation law
//! (`requests == completions + shed + abandoned`, always), non-vacuity
//! (an adverse pack must actually kill workers and force retries),
//! `--assert-recovered F` (fraction of retried requests rescued to an
//! on-time completion), and `--assert-no-hang S` (the run, including
//! shutdown drain past wedged workers, finishes within `S` wall seconds).

use crate::cli::Args;
use crate::config::{SchedulerKind, SizeBucket};
use crate::exp::benchsim::peak_rss_kb;
use crate::serve::{
    derive_pools, run_serve_sharded, AppFactory, AppServe, ChaosSpec, Compute, ServeConfig,
};
use crate::trace::production::{app_sources, Dataset, ProductionParams};
use crate::trace::AppTrace;
use crate::util::rng::Rng;

/// Inputs of one bench-serve run (every field feeds the JSON header).
#[derive(Clone, Debug)]
pub struct BenchServeSpec {
    pub dataset: Dataset,
    pub bucket: SizeBucket,
    /// Number of heavy-demand apps to replay (caps the Table 7 count).
    pub apps: usize,
    /// Demand scale factor (1.0 = paper-scale; CI uses a small fraction).
    pub demand_scale: f64,
    /// Simulated window length, seconds.
    pub duration: f64,
    /// Time-scale compressions to measure (sim seconds per wall second).
    pub scales: Vec<f64>,
    pub scheduler: SchedulerKind,
    /// Router shards (apps are partitioned round-robin across them).
    pub shards: usize,
    /// Per-run admission cap (0 = unbounded; CI arms it and asserts
    /// zero shed).
    pub queue_cap: usize,
    pub seed: u64,
}

/// One measured time-scale point.
#[derive(Clone, Debug)]
pub struct BenchServePoint {
    pub time_scale: f64,
    pub requests: u64,
    pub shed: u64,
    pub misses: u64,
    pub wall_seconds: f64,
    /// Served request throughput against the wall clock.
    pub req_per_sec_wall: f64,
    /// Worst wakeup lag behind the absolute pacing schedule, wall seconds.
    pub max_lag_wall: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
}

impl BenchServePoint {
    pub fn shed_fraction(&self) -> f64 {
        self.shed as f64 / self.requests.max(1) as f64
    }
}

/// The `spork bench-serve` report, written to `BENCH_serve.json`.
#[derive(Clone, Debug)]
pub struct BenchServeReport {
    pub scheduler: String,
    pub dataset: String,
    pub bucket: String,
    pub apps: usize,
    pub shards: usize,
    pub queue_cap: usize,
    pub sim_seconds: f64,
    pub peak_rss_kb: u64,
    pub points: Vec<BenchServePoint>,
}

impl BenchServeReport {
    pub fn to_json(&self) -> String {
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "    {{\"time_scale\": {}, \"requests\": {}, \"shed\": {}, \
                     \"shed_fraction\": {:.6}, \"misses\": {}, \
                     \"wall_seconds\": {:.3}, \"req_per_sec_wall\": {:.1}, \
                     \"max_lag_wall\": {:.4}, \"p50_ms\": {:.3}, \
                     \"p99_ms\": {:.3}, \"p999_ms\": {:.3}}}",
                    p.time_scale,
                    p.requests,
                    p.shed,
                    p.shed_fraction(),
                    p.misses,
                    p.wall_seconds,
                    p.req_per_sec_wall,
                    p.max_lag_wall,
                    p.p50_ms,
                    p.p99_ms,
                    p.p999_ms,
                )
            })
            .collect();
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"dataset\": \"{}\",\n  \
             \"bucket\": \"{}\",\n  \"apps\": {},\n  \"shards\": {},\n  \
             \"queue_cap\": {},\n  \"sim_seconds\": {},\n  \
             \"peak_rss_kb\": {},\n  \"points\": [\n{}\n  ]\n}}\n",
            self.scheduler,
            self.dataset,
            self.bucket,
            self.apps,
            self.shards,
            self.queue_cap,
            self.sim_seconds,
            self.peak_rss_kb,
            points.join(",\n"),
        )
    }

    /// The replay-fidelity tripwire: every point's worst wakeup lag must
    /// stay within `cap` wall seconds. Vacuity-guarded: a report with no
    /// points, or one that served nothing, demonstrates nothing.
    pub fn assert_max_lag(&self, cap: f64) -> Result<(), String> {
        if self.points.is_empty() {
            return Err("max-lag tripwire is vacuous: no time-scale points measured".into());
        }
        for p in &self.points {
            if p.requests == 0 {
                return Err(format!(
                    "max-lag tripwire is vacuous: the {}x point served zero \
                     requests — retune the bench workload",
                    p.time_scale
                ));
            }
            if p.max_lag_wall > cap {
                return Err(format!(
                    "replay lag regression: at {}x the router woke {:.3}s behind \
                     its pacing schedule (cap {cap}s) — batched admission or \
                     absolute-deadline pacing is no longer keeping up",
                    p.time_scale, p.max_lag_wall
                ));
            }
        }
        Ok(())
    }

    /// The backpressure tripwire: shed fraction must stay at or below
    /// `max_fraction` at every point. Only meaningful with an *armed*
    /// queue cap — with `queue_cap == 0` shedding is impossible and the
    /// assertion would pass vacuously, so that configuration is rejected.
    pub fn assert_shed_fraction(&self, max_fraction: f64) -> Result<(), String> {
        if self.queue_cap == 0 {
            return Err(
                "shed tripwire is vacuous: --queue-cap 0 can never shed; arm a \
                 cap for --assert-shed to demonstrate anything"
                    .into(),
            );
        }
        if self.points.is_empty() {
            return Err("shed tripwire is vacuous: no time-scale points measured".into());
        }
        for p in &self.points {
            let f = p.shed_fraction();
            if f > max_fraction {
                return Err(format!(
                    "shed regression: at {}x the router shed {} of {} requests \
                     ({:.2}%, cap {:.2}%) — the queue cap is biting in a \
                     configuration provisioned not to shed",
                    p.time_scale,
                    p.shed,
                    p.requests,
                    f * 100.0,
                    max_fraction * 100.0
                ));
            }
        }
        Ok(())
    }
}

/// The chaos-axis report (`--chaos <pack>`), written to
/// `BENCH_serve_chaos.json`: one fault pack replayed through the sharded
/// paced router at the bench's highest compression, with the fault plan's
/// digest and both *planned* and *applied* counts — everything
/// `tools/scenario_oracle.py verify-serve` needs to rebuild the per-app
/// plans from scratch and audit that the run replayed exactly them.
#[derive(Clone, Debug)]
pub struct ChaosBenchReport {
    pub pack: String,
    /// Whether the pack can fault at all (false only for `fault-free`);
    /// gates the non-vacuity checks in [`Self::verify`].
    pub adverse: bool,
    pub seed_base: u64,
    pub seed: u64,
    pub apps: usize,
    pub shards: usize,
    pub time_scale: f64,
    pub sim_seconds: f64,
    /// Merged plan digest: per-app digests folded in app-index order.
    pub digest: u64,
    pub planned_price_ticks: u64,
    pub planned_preemptions: u64,
    pub planned_failures: u64,
    /// Faults that actually struck a live worker (≤ planned).
    pub preemptions: u64,
    pub worker_failures: u64,
    pub requests: u64,
    pub completions: u64,
    pub shed: u64,
    pub abandoned: u64,
    pub retries: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub quarantines: u64,
    pub recovered_deadline_hits: u64,
    pub misses: u64,
    pub wall_seconds: f64,
}

impl ChaosBenchReport {
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"pack\": \"{}\",\n  \"adverse\": {},\n  \"seed_base\": {},\n  \
             \"seed\": {},\n  \"apps\": {},\n  \"shards\": {},\n  \
             \"time_scale\": {},\n  \"sim_seconds\": {},\n  \
             \"plan_digest\": \"{:016x}\",\n  \"planned_price_ticks\": {},\n  \
             \"planned_preemptions\": {},\n  \"planned_failures\": {},\n  \
             \"preemptions\": {},\n  \"worker_failures\": {},\n  \
             \"requests\": {},\n  \"completions\": {},\n  \"shed\": {},\n  \
             \"abandoned\": {},\n  \"retries\": {},\n  \"hedges\": {},\n  \
             \"hedge_wins\": {},\n  \"quarantines\": {},\n  \
             \"recovered_deadline_hits\": {},\n  \"misses\": {},\n  \
             \"wall_seconds\": {:.3}\n}}\n",
            self.pack,
            self.adverse,
            self.seed_base,
            self.seed,
            self.apps,
            self.shards,
            self.time_scale,
            self.sim_seconds,
            self.digest,
            self.planned_price_ticks,
            self.planned_preemptions,
            self.planned_failures,
            self.preemptions,
            self.worker_failures,
            self.requests,
            self.completions,
            self.shed,
            self.abandoned,
            self.retries,
            self.hedges,
            self.hedge_wins,
            self.quarantines,
            self.recovered_deadline_hits,
            self.misses,
            self.wall_seconds,
        )
    }

    /// The resilience tripwire proper. Always enforced: the extended
    /// conservation law `requests == completions + shed + abandoned`
    /// (retries re-dispatch an already-admitted request and must never
    /// mint a new one) and `hedge_wins <= hedges`. For an adverse pack it
    /// is additionally *non-vacuous*: the plan must contain faults, at
    /// least one must have struck a live worker, and at least one retry
    /// must have been exercised — a chaos run that never hurt anything
    /// proves nothing about recovery.
    pub fn verify(&self) -> Result<(), String> {
        let accounted = self.completions + self.shed + self.abandoned;
        if self.requests != accounted {
            return Err(format!(
                "conservation violated: {} requests != {} completions + {} shed \
                 + {} abandoned ({} accounted)",
                self.requests, self.completions, self.shed, self.abandoned, accounted
            ));
        }
        if self.hedge_wins > self.hedges {
            return Err(format!(
                "hedge accounting violated: {} wins > {} hedges",
                self.hedge_wins, self.hedges
            ));
        }
        if self.adverse {
            if self.planned_preemptions + self.planned_failures == 0 {
                return Err(format!(
                    "chaos tripwire is vacuous: pack '{}' planned zero \
                     kills over {} sim-s — lengthen the window",
                    self.pack, self.sim_seconds
                ));
            }
            if self.preemptions + self.worker_failures == 0 {
                return Err(format!(
                    "chaos tripwire is vacuous: {} kills were planned but \
                     none struck a live worker — the workload never keeps \
                     workers busy; retune it",
                    self.planned_preemptions + self.planned_failures
                ));
            }
            if self.retries == 0 {
                return Err(
                    "chaos tripwire is vacuous: faults struck but no retry was \
                     exercised — kills never caught a request in flight"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// `--assert-recovered F`: of the re-dispatches the fault plan forced,
    /// at least fraction `F` must have still completed on time
    /// (`recovered_deadline_hits` also counts hedge rescues, so the ratio
    /// can exceed 1). Vacuity-guarded: zero retries demonstrates nothing.
    pub fn assert_recovered(&self, min_fraction: f64) -> Result<(), String> {
        if self.retries == 0 {
            return Err(
                "recovery tripwire is vacuous: the run exercised zero retries; \
                 use an adverse pack / longer window"
                    .into(),
            );
        }
        let ratio = self.recovered_deadline_hits as f64 / self.retries as f64;
        if ratio < min_fraction {
            return Err(format!(
                "recovery regression: only {} of {} retried requests were \
                 rescued to an on-time completion ({:.2} < floor {:.2})",
                self.recovered_deadline_hits, self.retries, ratio, min_fraction
            ));
        }
        Ok(())
    }

    /// `--assert-no-hang S`: the whole chaos run — including shutdown
    /// drain past killed/stalled workers — must finish within `S` wall
    /// seconds. This is the liveness half of the resilience contract: a
    /// wedged worker may cost dropped completions, never a hung router.
    pub fn assert_no_hang(&self, max_wall: f64) -> Result<(), String> {
        if self.requests == 0 {
            return Err("no-hang tripwire is vacuous: the run served nothing".into());
        }
        if self.wall_seconds > max_wall {
            return Err(format!(
                "liveness regression: the chaos run took {:.3} wall-s \
                 (cap {max_wall}s) — shutdown is no longer grace-bounded",
                self.wall_seconds
            ));
        }
        Ok(())
    }
}

/// Run the chaos axis: one sharded paced replay of `pack` at the bench's
/// highest time-scale compression (the most hostile pacing regime).
pub fn run_bench_serve_chaos(
    spec: &BenchServeSpec,
    pack: &str,
) -> anyhow::Result<ChaosBenchReport> {
    let scale = spec.scales.iter().copied().fold(1.0f64, f64::max);
    let mut cfg = ServeConfig::defaults("unused-artifacts", scale);
    cfg.queue_cap = spec.queue_cap;
    let chaos = ChaosSpec::from_name(pack, spec.seed, 0).ok_or_else(|| {
        anyhow::anyhow!("unknown chaos pack '{pack}' (fault-free|mild|severe)")
    })?;
    let adverse = chaos.scenario.is_adverse();
    cfg.chaos = Some(chaos);
    let report = run_serve_sharded(&cfg, app_factories(spec), spec.shards, Compute::Paced)?;
    Ok(ChaosBenchReport {
        pack: report.chaos.pack.clone(),
        adverse,
        seed_base: report.chaos.seed_base,
        seed: report.chaos.seed,
        apps: spec.apps,
        shards: spec.shards,
        time_scale: scale,
        sim_seconds: spec.duration,
        digest: report.chaos.digest,
        planned_price_ticks: report.chaos.price_ticks,
        planned_preemptions: report.chaos.preemptions,
        planned_failures: report.chaos.failures,
        preemptions: report.preemptions,
        worker_failures: report.worker_failures,
        requests: report.requests,
        completions: report.completions,
        shed: report.shed,
        abandoned: report.abandoned,
        retries: report.retries,
        hedges: report.hedges,
        hedge_wins: report.hedge_wins,
        quarantines: report.quarantines,
        recovered_deadline_hits: report.recovered_deadline_hits,
        misses: report.misses,
        wall_seconds: report.wall_seconds,
    })
}

/// Build the per-app factories for one run. Each factory regenerates the
/// app population from `(params, seed)` and takes its own app — sources
/// are not `Send` or `Clone`, and regeneration is cheap (rate grids
/// only), so determinism costs nothing. Pools are derived per app from
/// its materialized trace, exactly like `spork serve` derives them.
fn app_factories(spec: &BenchServeSpec) -> Vec<AppFactory> {
    let params = ProductionParams {
        dataset: spec.dataset,
        bucket: spec.bucket,
        duration: spec.duration,
        scale: spec.demand_scale,
        max_apps: Some(spec.apps),
    };
    let seed = spec.seed;
    // Nominal time scale: factories only use the config for the
    // platform-derived `sim_config`; the runner's config governs pacing.
    let cfg = ServeConfig::defaults("unused-artifacts", 1.0);
    let n_apps = spec.apps.min(spec.dataset.app_count(spec.bucket));
    (0..n_apps)
        .map(|i| {
            let kind = spec.scheduler.clone();
            let cfg = cfg.clone();
            Box::new(move || {
                let mut rng = Rng::new(seed);
                let mut sources = app_sources(&params, &mut rng);
                let mut src = sources.swap_remove(i);
                let trace = AppTrace::from_source(&mut src);
                let (pool_cpus, pool_fpgas) = derive_pools(&cfg.platform, &trace);
                let sim_cfg = cfg.sim_config(pool_cpus, pool_fpgas);
                let policy = crate::sched::build(&kind, &sim_cfg, &trace);
                AppServe {
                    source: Box::new(trace.into_source()),
                    policy,
                    pool_cpus,
                    pool_fpgas,
                }
            }) as AppFactory
        })
        .collect()
}

/// Run the bench: one sharded paced replay per time scale.
pub fn run_bench_serve(spec: &BenchServeSpec) -> anyhow::Result<BenchServeReport> {
    let mut points = Vec::with_capacity(spec.scales.len());
    for &scale in &spec.scales {
        let mut cfg = ServeConfig::defaults("unused-artifacts", scale);
        cfg.queue_cap = spec.queue_cap;
        let report = run_serve_sharded(&cfg, app_factories(spec), spec.shards, Compute::Paced)?;
        points.push(BenchServePoint {
            time_scale: scale,
            requests: report.requests,
            shed: report.shed,
            misses: report.misses,
            wall_seconds: report.wall_seconds,
            req_per_sec_wall: report.requests as f64 / report.wall_seconds.max(1e-9),
            max_lag_wall: report.max_lag_wall,
            p50_ms: report.latency_ms.percentile(50.0),
            p99_ms: report.latency_ms.percentile(99.0),
            p999_ms: report.latency_ms.percentile(99.9),
        });
    }
    Ok(BenchServeReport {
        scheduler: spec.scheduler.name(),
        dataset: spec.dataset.name().to_string(),
        bucket: spec.bucket.name().to_string(),
        apps: spec.apps,
        shards: spec.shards,
        queue_cap: spec.queue_cap,
        sim_seconds: spec.duration,
        peak_rss_kb: peak_rss_kb(),
        points,
    })
}

/// Parse the `--scales` comma list ("1,10,100").
fn parse_scales(spec: &str) -> Result<Vec<f64>, String> {
    spec.split(',')
        .map(|t| {
            let t = t.trim();
            match t.parse::<f64>() {
                Ok(s) if s > 0.0 && s.is_finite() => Ok(s),
                _ => Err(format!("--scales: invalid time scale '{t}'")),
            }
        })
        .collect()
}

/// `spork bench-serve` CLI entrypoint.
pub fn cmd_bench_serve(args: &Args) -> Result<(), String> {
    let dataset_name = args.str_or("dataset", "azure");
    let dataset = Dataset::from_name(&dataset_name)
        .ok_or(format!("unknown dataset '{dataset_name}' (azure|alibaba)"))?;
    let bucket_name = args.str_or("bucket", "short");
    let bucket = SizeBucket::from_name(&bucket_name)
        .ok_or(format!("unknown bucket '{bucket_name}' (short|medium|long)"))?;
    let apps = args.usize_or("apps", 8)?;
    if apps == 0 {
        return Err("--apps must be > 0".into());
    }
    // The generator caps the population at the dataset's heavy-demand app
    // count; clamp here so the report's `apps` matches what actually ran.
    let apps = apps.min(dataset.app_count(bucket));
    let demand_scale = args.f64_or("demand-scale", 0.05)?;
    let duration = args.f64_or("duration", 600.0)?;
    if !(duration > 0.0 && duration.is_finite()) {
        return Err("--duration must be a finite positive number".into());
    }
    let scales = parse_scales(&args.str_or("scales", "1,10,100"))?;
    let sched_name = args.str_or("scheduler", "spork-e");
    let scheduler = SchedulerKind::from_name(&sched_name)
        .ok_or(format!("unknown scheduler '{sched_name}'"))?;
    let shards = args.usize_or("shards", 4)?.max(1);
    let queue_cap = args.usize_or("queue-cap", 256)?;
    let seed = args.u64_or("seed", 1)?;
    let out = args.str_or("out", "BENCH_serve.json");
    let assert_max_lag = match args.get("assert-max-lag") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-max-lag: invalid lag cap '{v}'"))?,
        ),
        None => None,
    };
    let assert_shed = match args.get("assert-shed") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-shed: invalid shed fraction '{v}'"))?,
        ),
        None => None,
    };
    let chaos_pack = args.get("chaos").cloned();
    let chaos_out = args.str_or("chaos-out", "BENCH_serve_chaos.json");
    let assert_recovered = match args.get("assert-recovered") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| {
            format!("--assert-recovered: invalid recovered fraction '{v}'")
        })?),
        None => None,
    };
    let assert_no_hang = match args.get("assert-no-hang") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-no-hang: invalid wall cap '{v}'"))?,
        ),
        None => None,
    };
    if chaos_pack.is_none() && (assert_recovered.is_some() || assert_no_hang.is_some()) {
        return Err(
            "--assert-recovered/--assert-no-hang gate the chaos axis; pass \
             --chaos <pack> to run it"
                .into(),
        );
    }

    let spec = BenchServeSpec {
        dataset,
        bucket,
        apps,
        demand_scale,
        duration,
        scales,
        scheduler,
        shards,
        queue_cap,
        seed,
    };
    eprintln!(
        "replaying {} {} apps x {:.0} sim-s through {} ({} shards, queue cap {}) \
         at {:?}x...",
        spec.dataset.name(),
        spec.apps,
        spec.duration,
        spec.scheduler.display(),
        spec.shards,
        spec.queue_cap,
        spec.scales,
    );
    let report = run_bench_serve(&spec).map_err(|e| e.to_string())?;
    let json = report.to_json();
    std::fs::write(&out, &json).map_err(|e| format!("writing {out}: {e}"))?;
    for p in &report.points {
        println!(
            "  {:>5}x: {} requests in {:.2} wall-s = {:.0} req/s, {} shed, \
             max lag {:.3}s, p50/p99/p999 {:.1}/{:.1}/{:.1} ms",
            p.time_scale,
            p.requests,
            p.wall_seconds,
            p.req_per_sec_wall,
            p.shed,
            p.max_lag_wall,
            p.p50_ms,
            p.p99_ms,
            p.p999_ms,
        );
    }
    println!("-> {out} (peak RSS {} kB)", report.peak_rss_kb);
    if let Some(cap) = assert_max_lag {
        report.assert_max_lag(cap)?;
        println!("  lag tripwire: every point woke <= {cap}s behind schedule");
    }
    if let Some(frac) = assert_shed {
        report.assert_shed_fraction(frac)?;
        println!(
            "  shed tripwire: shed fraction <= {frac} at every point \
             (queue cap {} armed)",
            report.queue_cap
        );
    }
    if let Some(pack) = chaos_pack {
        eprintln!(
            "chaos axis: replaying the '{pack}' pack at {}x...",
            spec.scales.iter().copied().fold(1.0f64, f64::max)
        );
        let c = run_bench_serve_chaos(&spec, &pack).map_err(|e| e.to_string())?;
        let cj = c.to_json();
        std::fs::write(&chaos_out, &cj).map_err(|e| format!("writing {chaos_out}: {e}"))?;
        println!(
            "  chaos '{}' (plan {:016x}): {} requests = {} completed + {} shed \
             + {} abandoned; {}/{} kills applied, {} retries, {} hedges \
             ({} won), {} quarantines, {} recovered hits in {:.2} wall-s",
            c.pack,
            c.digest,
            c.requests,
            c.completions,
            c.shed,
            c.abandoned,
            c.preemptions + c.worker_failures,
            c.planned_preemptions + c.planned_failures,
            c.retries,
            c.hedges,
            c.hedge_wins,
            c.quarantines,
            c.recovered_deadline_hits,
            c.wall_seconds,
        );
        println!("-> {chaos_out}");
        c.verify()?;
        if c.adverse {
            println!("  chaos tripwire: conservation holds and the pack bit");
        } else {
            println!("  chaos tripwire: conservation holds (parity pack, nothing planned)");
        }
        if let Some(f) = assert_recovered {
            c.assert_recovered(f)?;
            println!("  recovery tripwire: >= {f} of retried requests rescued on time");
        }
        if let Some(s) = assert_no_hang {
            c.assert_no_hang(s)?;
            println!("  liveness tripwire: chaos run finished within {s} wall-s");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(scales: Vec<f64>, queue_cap: usize) -> BenchServeSpec {
        BenchServeSpec {
            dataset: Dataset::AzureFunctions,
            bucket: SizeBucket::Short,
            apps: 3,
            demand_scale: 0.02,
            duration: 60.0,
            scales,
            scheduler: SchedulerKind::spork_e(),
            shards: 2,
            queue_cap,
            seed: 11,
        }
    }

    #[test]
    fn bench_serve_reports_and_serializes() {
        // High compression so the paced replay finishes in well under a
        // wall second.
        let r = run_bench_serve(&tiny_spec(vec![1000.0], 256)).unwrap();
        assert_eq!(r.points.len(), 1);
        let p = &r.points[0];
        assert!(p.requests > 0, "bench workload served nothing");
        assert_eq!(p.shed, 0, "unsaturated config must not shed");
        assert!(p.req_per_sec_wall > 0.0);
        assert!(p.p50_ms <= p.p99_ms && p.p99_ms <= p.p999_ms);
        let j = r.to_json();
        assert!(j.contains("\"req_per_sec_wall\""));
        assert!(j.contains("\"max_lag_wall\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn request_counts_agree_across_time_scales() {
        // Pacing compresses wall time only — the model must serve the
        // identical workload at any compression.
        let r = run_bench_serve(&tiny_spec(vec![500.0, 2000.0], 256)).unwrap();
        assert_eq!(r.points[0].requests, r.points[1].requests);
        assert_eq!(r.points[0].misses, r.points[1].misses);
        assert_eq!(r.points[0].shed, r.points[1].shed);
    }

    #[test]
    fn tripwires_gate_and_guard_vacuity() {
        let r = run_bench_serve(&tiny_spec(vec![1000.0], 256)).unwrap();
        assert!(r.assert_max_lag(1e6).is_ok());
        assert!(r.assert_max_lag(-1.0).is_err(), "no lag can beat a negative cap");
        assert!(r.assert_shed_fraction(0.0).is_ok());
        // An unarmed cap makes the shed assertion meaningless.
        let unarmed = run_bench_serve(&tiny_spec(vec![1000.0], 0)).unwrap();
        let err = unarmed.assert_shed_fraction(0.0).unwrap_err();
        assert!(err.contains("vacuous"), "unexpected error: {err}");
        // An empty report demonstrates nothing either.
        let empty = BenchServeReport {
            points: Vec::new(),
            ..r.clone()
        };
        assert!(empty.assert_max_lag(1.0).is_err());
        assert!(empty.assert_shed_fraction(0.5).is_err());
    }

    #[test]
    fn chaos_axis_conserves_and_serializes() {
        // A long severe window with enough demand that kills catch
        // requests in flight: the non-vacuity checks in `verify` must
        // pass, not just conservation.
        let mut spec = tiny_spec(vec![20000.0], 256);
        spec.duration = 600.0;
        spec.demand_scale = 0.1;
        let c = run_bench_serve_chaos(&spec, "severe").unwrap();
        assert!(c.adverse);
        assert!(c.requests > 0);
        c.verify().expect("severe chaos must be non-vacuous and conserve");
        assert!(c.digest != 0, "an adverse plan cannot hash to the empty digest");
        assert!(c.hedge_wins <= c.hedges);
        let j = c.to_json();
        assert!(j.contains("\"plan_digest\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "chaos JSON must parse");
        // Determinism: the chaos point is a pure function of the spec.
        let again = run_bench_serve_chaos(&spec, "severe").unwrap();
        assert_eq!(c.digest, again.digest);
        assert_eq!(c.requests, again.requests);
        assert_eq!(c.retries, again.retries);
        assert_eq!(c.abandoned, again.abandoned);
    }

    #[test]
    fn fault_free_chaos_axis_is_quiet_and_vacuity_guarded() {
        let c = run_bench_serve_chaos(&tiny_spec(vec![5000.0], 256), "fault-free").unwrap();
        assert!(!c.adverse);
        assert_eq!(c.digest, 0, "the parity pack plans nothing");
        assert_eq!(c.preemptions + c.worker_failures, 0);
        assert_eq!(c.retries, 0);
        c.verify().expect("conservation must hold without faults too");
        // Asserting recovery with zero retries would be a vacuous pass.
        assert!(c.assert_recovered(0.1).unwrap_err().contains("vacuous"));
        assert!(c.assert_no_hang(1e6).is_ok());
        assert!(c.assert_no_hang(0.0).is_err(), "no run beats a zero wall cap");
        assert!(run_bench_serve_chaos(&tiny_spec(vec![1000.0], 256), "bogus").is_err());
    }

    #[test]
    fn scales_parse() {
        assert_eq!(parse_scales("1, 10,100").unwrap(), vec![1.0, 10.0, 100.0]);
        assert!(parse_scales("10,zoom").is_err());
        assert!(parse_scales("0").is_err());
        assert!(parse_scales("-5").is_err());
    }

    #[test]
    fn overload_sheds_and_conserves() {
        // A queue cap of 1 in-flight under a dense workload must shed
        // (any two overlapping requests trip it); what it sheds must stay
        // conserved in the request count.
        let mut spec = tiny_spec(vec![2000.0], 1);
        spec.demand_scale = 0.5;
        let r = run_bench_serve(&spec).unwrap();
        let p = &r.points[0];
        assert!(p.shed > 0, "cap 1 should shed under this workload");
        assert!(p.shed < p.requests, "some requests must still be served");
        assert!(p.shed_fraction() > 0.0 && p.shed_fraction() < 1.0);
    }
}
