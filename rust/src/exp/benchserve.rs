//! `spork bench-serve`: the serve-path line-rate harness.
//!
//! Replays a production-style workload (the Table 7 generator) through
//! the **sharded real-time router** (`serve::run_serve_sharded`,
//! [`Compute::Paced`]: full pacing loop, no PJRT) at one or more
//! time-scale compressions and reports, per scale: requests served,
//! requests/second of wall time, shed count and fraction, worst replay
//! lag, and latency percentiles to p999 — to `BENCH_serve.json`,
//! mirroring `bench-sim`'s role for the simulator.
//!
//! The CI tripwires are `--assert-max-lag L` (the router must never wake
//! more than `L` wall seconds behind its absolute pacing deadline — the
//! batched-admission and drift-free-pacing guarantees, measured) and
//! `--assert-shed F` (in an unsaturated configuration an *armed* queue
//! cap must shed at most fraction `F`; `--assert-shed 0` with a nonzero
//! `--queue-cap` proves backpressure stays quiet exactly when it should).
//!
//! Every model input is a pure function of `(params, seed, app index)`
//! — each run regenerates its sources from scratch, so points at
//! different time scales serve bit-identical workloads and any request
//! count disagreement across scales is a pacing bug, not noise.

use crate::cli::Args;
use crate::config::{SchedulerKind, SizeBucket};
use crate::exp::benchsim::peak_rss_kb;
use crate::serve::{derive_pools, run_serve_sharded, AppFactory, AppServe, Compute, ServeConfig};
use crate::trace::production::{app_sources, Dataset, ProductionParams};
use crate::trace::AppTrace;
use crate::util::rng::Rng;

/// Inputs of one bench-serve run (every field feeds the JSON header).
#[derive(Clone, Debug)]
pub struct BenchServeSpec {
    pub dataset: Dataset,
    pub bucket: SizeBucket,
    /// Number of heavy-demand apps to replay (caps the Table 7 count).
    pub apps: usize,
    /// Demand scale factor (1.0 = paper-scale; CI uses a small fraction).
    pub demand_scale: f64,
    /// Simulated window length, seconds.
    pub duration: f64,
    /// Time-scale compressions to measure (sim seconds per wall second).
    pub scales: Vec<f64>,
    pub scheduler: SchedulerKind,
    /// Router shards (apps are partitioned round-robin across them).
    pub shards: usize,
    /// Per-run admission cap (0 = unbounded; CI arms it and asserts
    /// zero shed).
    pub queue_cap: usize,
    pub seed: u64,
}

/// One measured time-scale point.
#[derive(Clone, Debug)]
pub struct BenchServePoint {
    pub time_scale: f64,
    pub requests: u64,
    pub shed: u64,
    pub misses: u64,
    pub wall_seconds: f64,
    /// Served request throughput against the wall clock.
    pub req_per_sec_wall: f64,
    /// Worst wakeup lag behind the absolute pacing schedule, wall seconds.
    pub max_lag_wall: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
}

impl BenchServePoint {
    pub fn shed_fraction(&self) -> f64 {
        self.shed as f64 / self.requests.max(1) as f64
    }
}

/// The `spork bench-serve` report, written to `BENCH_serve.json`.
#[derive(Clone, Debug)]
pub struct BenchServeReport {
    pub scheduler: String,
    pub dataset: String,
    pub bucket: String,
    pub apps: usize,
    pub shards: usize,
    pub queue_cap: usize,
    pub sim_seconds: f64,
    pub peak_rss_kb: u64,
    pub points: Vec<BenchServePoint>,
}

impl BenchServeReport {
    pub fn to_json(&self) -> String {
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "    {{\"time_scale\": {}, \"requests\": {}, \"shed\": {}, \
                     \"shed_fraction\": {:.6}, \"misses\": {}, \
                     \"wall_seconds\": {:.3}, \"req_per_sec_wall\": {:.1}, \
                     \"max_lag_wall\": {:.4}, \"p50_ms\": {:.3}, \
                     \"p99_ms\": {:.3}, \"p999_ms\": {:.3}}}",
                    p.time_scale,
                    p.requests,
                    p.shed,
                    p.shed_fraction(),
                    p.misses,
                    p.wall_seconds,
                    p.req_per_sec_wall,
                    p.max_lag_wall,
                    p.p50_ms,
                    p.p99_ms,
                    p.p999_ms,
                )
            })
            .collect();
        format!(
            "{{\n  \"scheduler\": \"{}\",\n  \"dataset\": \"{}\",\n  \
             \"bucket\": \"{}\",\n  \"apps\": {},\n  \"shards\": {},\n  \
             \"queue_cap\": {},\n  \"sim_seconds\": {},\n  \
             \"peak_rss_kb\": {},\n  \"points\": [\n{}\n  ]\n}}\n",
            self.scheduler,
            self.dataset,
            self.bucket,
            self.apps,
            self.shards,
            self.queue_cap,
            self.sim_seconds,
            self.peak_rss_kb,
            points.join(",\n"),
        )
    }

    /// The replay-fidelity tripwire: every point's worst wakeup lag must
    /// stay within `cap` wall seconds. Vacuity-guarded: a report with no
    /// points, or one that served nothing, demonstrates nothing.
    pub fn assert_max_lag(&self, cap: f64) -> Result<(), String> {
        if self.points.is_empty() {
            return Err("max-lag tripwire is vacuous: no time-scale points measured".into());
        }
        for p in &self.points {
            if p.requests == 0 {
                return Err(format!(
                    "max-lag tripwire is vacuous: the {}x point served zero \
                     requests — retune the bench workload",
                    p.time_scale
                ));
            }
            if p.max_lag_wall > cap {
                return Err(format!(
                    "replay lag regression: at {}x the router woke {:.3}s behind \
                     its pacing schedule (cap {cap}s) — batched admission or \
                     absolute-deadline pacing is no longer keeping up",
                    p.time_scale, p.max_lag_wall
                ));
            }
        }
        Ok(())
    }

    /// The backpressure tripwire: shed fraction must stay at or below
    /// `max_fraction` at every point. Only meaningful with an *armed*
    /// queue cap — with `queue_cap == 0` shedding is impossible and the
    /// assertion would pass vacuously, so that configuration is rejected.
    pub fn assert_shed_fraction(&self, max_fraction: f64) -> Result<(), String> {
        if self.queue_cap == 0 {
            return Err(
                "shed tripwire is vacuous: --queue-cap 0 can never shed; arm a \
                 cap for --assert-shed to demonstrate anything"
                    .into(),
            );
        }
        if self.points.is_empty() {
            return Err("shed tripwire is vacuous: no time-scale points measured".into());
        }
        for p in &self.points {
            let f = p.shed_fraction();
            if f > max_fraction {
                return Err(format!(
                    "shed regression: at {}x the router shed {} of {} requests \
                     ({:.2}%, cap {:.2}%) — the queue cap is biting in a \
                     configuration provisioned not to shed",
                    p.time_scale,
                    p.shed,
                    p.requests,
                    f * 100.0,
                    max_fraction * 100.0
                ));
            }
        }
        Ok(())
    }
}

/// Build the per-app factories for one run. Each factory regenerates the
/// app population from `(params, seed)` and takes its own app — sources
/// are not `Send` or `Clone`, and regeneration is cheap (rate grids
/// only), so determinism costs nothing. Pools are derived per app from
/// its materialized trace, exactly like `spork serve` derives them.
fn app_factories(spec: &BenchServeSpec) -> Vec<AppFactory> {
    let params = ProductionParams {
        dataset: spec.dataset,
        bucket: spec.bucket,
        duration: spec.duration,
        scale: spec.demand_scale,
        max_apps: Some(spec.apps),
    };
    let seed = spec.seed;
    // Nominal time scale: factories only use the config for the
    // platform-derived `sim_config`; the runner's config governs pacing.
    let cfg = ServeConfig::defaults("unused-artifacts", 1.0);
    let n_apps = spec.apps.min(spec.dataset.app_count(spec.bucket));
    (0..n_apps)
        .map(|i| {
            let kind = spec.scheduler.clone();
            let cfg = cfg.clone();
            Box::new(move || {
                let mut rng = Rng::new(seed);
                let mut sources = app_sources(&params, &mut rng);
                let mut src = sources.swap_remove(i);
                let trace = AppTrace::from_source(&mut src);
                let (pool_cpus, pool_fpgas) = derive_pools(&cfg.platform, &trace);
                let sim_cfg = cfg.sim_config(pool_cpus, pool_fpgas);
                let policy = crate::sched::build(&kind, &sim_cfg, &trace);
                AppServe {
                    source: Box::new(trace.into_source()),
                    policy,
                    pool_cpus,
                    pool_fpgas,
                }
            }) as AppFactory
        })
        .collect()
}

/// Run the bench: one sharded paced replay per time scale.
pub fn run_bench_serve(spec: &BenchServeSpec) -> anyhow::Result<BenchServeReport> {
    let mut points = Vec::with_capacity(spec.scales.len());
    for &scale in &spec.scales {
        let mut cfg = ServeConfig::defaults("unused-artifacts", scale);
        cfg.queue_cap = spec.queue_cap;
        let report = run_serve_sharded(&cfg, app_factories(spec), spec.shards, Compute::Paced)?;
        points.push(BenchServePoint {
            time_scale: scale,
            requests: report.requests,
            shed: report.shed,
            misses: report.misses,
            wall_seconds: report.wall_seconds,
            req_per_sec_wall: report.requests as f64 / report.wall_seconds.max(1e-9),
            max_lag_wall: report.max_lag_wall,
            p50_ms: report.latency_ms.percentile(50.0),
            p99_ms: report.latency_ms.percentile(99.0),
            p999_ms: report.latency_ms.percentile(99.9),
        });
    }
    Ok(BenchServeReport {
        scheduler: spec.scheduler.name(),
        dataset: spec.dataset.name().to_string(),
        bucket: spec.bucket.name().to_string(),
        apps: spec.apps,
        shards: spec.shards,
        queue_cap: spec.queue_cap,
        sim_seconds: spec.duration,
        peak_rss_kb: peak_rss_kb(),
        points,
    })
}

/// Parse the `--scales` comma list ("1,10,100").
fn parse_scales(spec: &str) -> Result<Vec<f64>, String> {
    spec.split(',')
        .map(|t| {
            let t = t.trim();
            match t.parse::<f64>() {
                Ok(s) if s > 0.0 && s.is_finite() => Ok(s),
                _ => Err(format!("--scales: invalid time scale '{t}'")),
            }
        })
        .collect()
}

/// `spork bench-serve` CLI entrypoint.
pub fn cmd_bench_serve(args: &Args) -> Result<(), String> {
    let dataset_name = args.str_or("dataset", "azure");
    let dataset = Dataset::from_name(&dataset_name)
        .ok_or(format!("unknown dataset '{dataset_name}' (azure|alibaba)"))?;
    let bucket_name = args.str_or("bucket", "short");
    let bucket = SizeBucket::from_name(&bucket_name)
        .ok_or(format!("unknown bucket '{bucket_name}' (short|medium|long)"))?;
    let apps = args.usize_or("apps", 8)?;
    if apps == 0 {
        return Err("--apps must be > 0".into());
    }
    // The generator caps the population at the dataset's heavy-demand app
    // count; clamp here so the report's `apps` matches what actually ran.
    let apps = apps.min(dataset.app_count(bucket));
    let demand_scale = args.f64_or("demand-scale", 0.05)?;
    let duration = args.f64_or("duration", 600.0)?;
    if !(duration > 0.0 && duration.is_finite()) {
        return Err("--duration must be a finite positive number".into());
    }
    let scales = parse_scales(&args.str_or("scales", "1,10,100"))?;
    let sched_name = args.str_or("scheduler", "spork-e");
    let scheduler = SchedulerKind::from_name(&sched_name)
        .ok_or(format!("unknown scheduler '{sched_name}'"))?;
    let shards = args.usize_or("shards", 4)?.max(1);
    let queue_cap = args.usize_or("queue-cap", 256)?;
    let seed = args.u64_or("seed", 1)?;
    let out = args.str_or("out", "BENCH_serve.json");
    let assert_max_lag = match args.get("assert-max-lag") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-max-lag: invalid lag cap '{v}'"))?,
        ),
        None => None,
    };
    let assert_shed = match args.get("assert-shed") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| format!("--assert-shed: invalid shed fraction '{v}'"))?,
        ),
        None => None,
    };

    let spec = BenchServeSpec {
        dataset,
        bucket,
        apps,
        demand_scale,
        duration,
        scales,
        scheduler,
        shards,
        queue_cap,
        seed,
    };
    eprintln!(
        "replaying {} {} apps x {:.0} sim-s through {} ({} shards, queue cap {}) \
         at {:?}x...",
        spec.dataset.name(),
        spec.apps,
        spec.duration,
        spec.scheduler.display(),
        spec.shards,
        spec.queue_cap,
        spec.scales,
    );
    let report = run_bench_serve(&spec).map_err(|e| e.to_string())?;
    let json = report.to_json();
    std::fs::write(&out, &json).map_err(|e| format!("writing {out}: {e}"))?;
    for p in &report.points {
        println!(
            "  {:>5}x: {} requests in {:.2} wall-s = {:.0} req/s, {} shed, \
             max lag {:.3}s, p50/p99/p999 {:.1}/{:.1}/{:.1} ms",
            p.time_scale,
            p.requests,
            p.wall_seconds,
            p.req_per_sec_wall,
            p.shed,
            p.max_lag_wall,
            p.p50_ms,
            p.p99_ms,
            p.p999_ms,
        );
    }
    println!("-> {out} (peak RSS {} kB)", report.peak_rss_kb);
    if let Some(cap) = assert_max_lag {
        report.assert_max_lag(cap)?;
        println!("  lag tripwire: every point woke <= {cap}s behind schedule");
    }
    if let Some(frac) = assert_shed {
        report.assert_shed_fraction(frac)?;
        println!(
            "  shed tripwire: shed fraction <= {frac} at every point \
             (queue cap {} armed)",
            report.queue_cap
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(scales: Vec<f64>, queue_cap: usize) -> BenchServeSpec {
        BenchServeSpec {
            dataset: Dataset::AzureFunctions,
            bucket: SizeBucket::Short,
            apps: 3,
            demand_scale: 0.02,
            duration: 60.0,
            scales,
            scheduler: SchedulerKind::spork_e(),
            shards: 2,
            queue_cap,
            seed: 11,
        }
    }

    #[test]
    fn bench_serve_reports_and_serializes() {
        // High compression so the paced replay finishes in well under a
        // wall second.
        let r = run_bench_serve(&tiny_spec(vec![1000.0], 256)).unwrap();
        assert_eq!(r.points.len(), 1);
        let p = &r.points[0];
        assert!(p.requests > 0, "bench workload served nothing");
        assert_eq!(p.shed, 0, "unsaturated config must not shed");
        assert!(p.req_per_sec_wall > 0.0);
        assert!(p.p50_ms <= p.p99_ms && p.p99_ms <= p.p999_ms);
        let j = r.to_json();
        assert!(j.contains("\"req_per_sec_wall\""));
        assert!(j.contains("\"max_lag_wall\""));
        assert!(crate::util::json::Json::parse(&j).is_ok(), "bench JSON must parse");
    }

    #[test]
    fn request_counts_agree_across_time_scales() {
        // Pacing compresses wall time only — the model must serve the
        // identical workload at any compression.
        let r = run_bench_serve(&tiny_spec(vec![500.0, 2000.0], 256)).unwrap();
        assert_eq!(r.points[0].requests, r.points[1].requests);
        assert_eq!(r.points[0].misses, r.points[1].misses);
        assert_eq!(r.points[0].shed, r.points[1].shed);
    }

    #[test]
    fn tripwires_gate_and_guard_vacuity() {
        let r = run_bench_serve(&tiny_spec(vec![1000.0], 256)).unwrap();
        assert!(r.assert_max_lag(1e6).is_ok());
        assert!(r.assert_max_lag(-1.0).is_err(), "no lag can beat a negative cap");
        assert!(r.assert_shed_fraction(0.0).is_ok());
        // An unarmed cap makes the shed assertion meaningless.
        let unarmed = run_bench_serve(&tiny_spec(vec![1000.0], 0)).unwrap();
        let err = unarmed.assert_shed_fraction(0.0).unwrap_err();
        assert!(err.contains("vacuous"), "unexpected error: {err}");
        // An empty report demonstrates nothing either.
        let empty = BenchServeReport {
            points: Vec::new(),
            ..r.clone()
        };
        assert!(empty.assert_max_lag(1.0).is_err());
        assert!(empty.assert_shed_fraction(0.5).is_err());
    }

    #[test]
    fn scales_parse() {
        assert_eq!(parse_scales("1, 10,100").unwrap(), vec![1.0, 10.0, 100.0]);
        assert!(parse_scales("10,zoom").is_err());
        assert!(parse_scales("0").is_err());
        assert!(parse_scales("-5").is_err());
    }

    #[test]
    fn overload_sheds_and_conserves() {
        // A queue cap of 1 in-flight under a dense workload must shed
        // (any two overlapping requests trip it); what it sheds must stay
        // conserved in the request count.
        let mut spec = tiny_spec(vec![2000.0], 1);
        spec.demand_scale = 0.5;
        let r = run_bench_serve(&spec).unwrap();
        let p = &r.points[0];
        assert!(p.shed > 0, "cap 1 should shed under this workload");
        assert!(p.shed < p.requests, "some requests must still be served");
        assert!(p.shed_fraction() > 0.0 && p.shed_fraction() < 1.0);
    }
}
