//! Table 8 (scheduler roster on production workloads) and Table 9
//! (dispatch policy ablation).

use super::common::{run_production, Cell, ExpCtx};
use crate::config::{
    DispatchPolicy, PlatformConfig, SchedulerKind, SimConfig, SizeBucket,
};
use crate::sched::{self, Objective};
use crate::sim::{self, IdealBaseline, Metrics};
use crate::trace::production::{self, Dataset, ProductionParams};
use crate::trace::AppTrace;
use crate::util::rng::Rng;
use crate::util::table::{pct, ratio, Table};

/// Generate one dataset x bucket workload at the context's scale. The
/// default (reduced) setting caps app counts and demand so the full
/// roster finishes on a laptop-class host; `--full` restores Table 7
/// populations and two-hour windows (see EXPERIMENTS.md for what ran).
pub fn workload(ctx: &ExpCtx, dataset: Dataset, bucket: SizeBucket, seed: u64) -> Vec<AppTrace> {
    let params = ProductionParams {
        dataset,
        bucket,
        duration: if ctx.full { 7200.0 } else { 1800.0 },
        scale: ctx.scale,
        max_apps: if ctx.full {
            None
        } else {
            Some(match bucket {
                SizeBucket::Short => 13,
                SizeBucket::Medium => 12,
                SizeBucket::Long => 8,
            })
        },
    };
    let mut rng = Rng::new(seed);
    production::generate(&params, &mut rng)
}

/// Table 8: full scheduler roster on short and medium production traces.
pub fn table8(ctx: &ExpCtx) -> Vec<Table> {
    let cfg = SimConfig::paper_default();
    let mut tables = Vec::new();
    for (bucket, tag) in [(SizeBucket::Short, "8a short"), (SizeBucket::Medium, "8b medium")] {
        let mut t = Table::new(
            &format!("Table {tag} requests: production workloads"),
            &[
                "Scheduler",
                "Azure eff", "Azure cost",
                "Alibaba eff", "Alibaba cost",
            ],
        );
        let azure = workload(ctx, Dataset::AzureFunctions, bucket, 11);
        let alibaba = workload(ctx, Dataset::AlibabaMicroservices, bucket, 13);
        for kind in SchedulerKind::table8_roster() {
            let az = run_production(&kind, &cfg, &azure);
            let al = run_production(&kind, &cfg, &alibaba);
            t.row(vec![
                kind.display(),
                pct(az.energy_eff),
                ratio(az.rel_cost),
                pct(al.energy_eff),
                ratio(al.rel_cost),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Table 9: dispatch policy ablation under SporkE's allocation logic.
pub fn table9(ctx: &ExpCtx) -> Vec<Table> {
    let cfg = SimConfig::paper_default();
    let rows: Vec<(Dataset, SizeBucket)> = vec![
        (Dataset::AzureFunctions, SizeBucket::Short),
        (Dataset::AzureFunctions, SizeBucket::Medium),
        (Dataset::AzureFunctions, SizeBucket::Long),
        (Dataset::AlibabaMicroservices, SizeBucket::Short),
        (Dataset::AlibabaMicroservices, SizeBucket::Medium),
    ];
    let mut t = Table::new(
        "Table 9: energy efficiency by dispatch policy (SporkE allocation)",
        &["Trace", "Round Robin", "Index Packing", "Spork (efficient-first)"],
    );
    for (dataset, bucket) in rows {
        let apps = workload(ctx, dataset, bucket, 17);
        let mut cells = Vec::new();
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::IndexPacking,
            DispatchPolicy::EfficientFirst,
        ] {
            cells.push(run_spork_with_dispatch(&cfg, &apps, policy));
        }
        t.row(vec![
            format!("{} ({})", dataset.name(), bucket.name()),
            pct(cells[0].energy_eff),
            pct(cells[1].energy_eff),
            pct(cells[2].energy_eff),
        ]);
    }
    vec![t]
}

/// SporkE allocation + a specific dispatch policy over a multi-app
/// workload.
pub fn run_spork_with_dispatch(
    cfg: &SimConfig,
    apps: &[AppTrace],
    policy: DispatchPolicy,
) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let mut total = Metrics::default();
    for app in apps {
        let mut s = sched::spork::Spork::new(cfg, Objective::energy()).with_dispatch(policy);
        let r = sim::run(app, cfg.clone(), &defaults, &mut s);
        total.merge(&r.metrics);
    }
    let ideal = IdealBaseline::for_work(total.total_work, &defaults);
    let mut cell = Cell::default();
    cell.add_run(&total, &ideal);
    cell.finish()
}
