//! Table 8 (scheduler roster on production workloads) and Table 9
//! (dispatch policy ablation).
//!
//! Workloads are generated once per table, then the (scheduler × dataset)
//! / (trace × policy) grids run through the parallel sweep engine, and
//! the per-app loops inside each cell fan out too: both levels draw
//! permits from the same process-wide bounded executor (DESIGN.md §14),
//! so nesting degrades gracefully instead of oversubscribing, and every
//! cell stays bit-identical to the serial loop for any `--jobs`.

use super::common::{profile_apps, run_production_profiles, Cell, ExpCtx};
use super::sweep::parallel_map;
use crate::config::{
    DispatchPolicy, PlatformConfig, SchedulerKind, SimConfig, SizeBucket,
};
use crate::sched::{self, Objective};
use crate::sim::{self, IdealBaseline, Metrics};
use crate::trace::production::{self, Dataset, ProductionParams};
use crate::trace::AppTrace;
use crate::util::rng::Rng;
use crate::util::table::{pct, ratio, Table};

/// Generate one dataset x bucket workload at the context's scale. The
/// default (reduced) setting caps app counts and demand so the full
/// roster finishes on a laptop-class host; `--full` restores Table 7
/// populations and two-hour windows (see EXPERIMENTS.md for what ran).
pub fn workload(ctx: &ExpCtx, dataset: Dataset, bucket: SizeBucket, seed: u64) -> Vec<AppTrace> {
    let params = ProductionParams {
        dataset,
        bucket,
        duration: if ctx.full { 7200.0 } else { 1800.0 },
        scale: ctx.scale,
        max_apps: if ctx.full {
            None
        } else {
            Some(match bucket {
                SizeBucket::Short => 13,
                SizeBucket::Medium => 12,
                SizeBucket::Long => 8,
            })
        },
    };
    let mut rng = Rng::new(seed);
    production::generate(&params, &mut rng)
}

/// Table 8: full scheduler roster on short and medium production traces.
pub fn table8(ctx: &ExpCtx) -> Vec<Table> {
    let cfg = SimConfig::paper_default();
    let roster = SchedulerKind::table8_roster();
    let mut tables = Vec::new();
    for (bucket, tag) in [(SizeBucket::Short, "8a short"), (SizeBucket::Medium, "8b medium")] {
        // Profile each app population once; the whole roster shares the
        // traces and per-interval bins (every kind used to re-stream each
        // app's arrivals for its oracle/fitting passes).
        let azure = profile_apps(workload(ctx, Dataset::AzureFunctions, bucket, 11), &cfg);
        let alibaba =
            profile_apps(workload(ctx, Dataset::AlibabaMicroservices, bucket, 13), &cfg);
        let cells = parallel_map(&roster, ctx.effective_jobs(), |_, kind| {
            (
                run_production_profiles(kind, &cfg, &azure),
                run_production_profiles(kind, &cfg, &alibaba),
            )
        });
        let mut t = Table::new(
            &format!("Table {tag} requests: production workloads"),
            &[
                "Scheduler",
                "Azure eff", "Azure cost",
                "Alibaba eff", "Alibaba cost",
            ],
        );
        for (kind, (az, al)) in roster.iter().zip(&cells) {
            t.row(vec![
                kind.display(),
                pct(az.energy_eff),
                ratio(az.rel_cost),
                pct(al.energy_eff),
                ratio(al.rel_cost),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Table 9: dispatch policy ablation under SporkE's allocation logic.
pub fn table9(ctx: &ExpCtx) -> Vec<Table> {
    let cfg = SimConfig::paper_default();
    let rows: Vec<(Dataset, SizeBucket)> = vec![
        (Dataset::AzureFunctions, SizeBucket::Short),
        (Dataset::AzureFunctions, SizeBucket::Medium),
        (Dataset::AzureFunctions, SizeBucket::Long),
        (Dataset::AlibabaMicroservices, SizeBucket::Short),
        (Dataset::AlibabaMicroservices, SizeBucket::Medium),
    ];
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::IndexPacking,
        DispatchPolicy::EfficientFirst,
    ];
    let workloads: Vec<Vec<AppTrace>> = rows
        .iter()
        .map(|&(dataset, bucket)| workload(ctx, dataset, bucket, 17))
        .collect();
    let units: Vec<(usize, DispatchPolicy)> = (0..rows.len())
        .flat_map(|i| policies.iter().map(move |&p| (i, p)))
        .collect();
    let cells = parallel_map(&units, ctx.effective_jobs(), |_, &(i, policy)| {
        run_spork_with_dispatch(&cfg, &workloads[i], policy)
    });

    let mut t = Table::new(
        "Table 9: energy efficiency by dispatch policy (SporkE allocation)",
        &["Trace", "Round Robin", "Index Packing", "Spork (efficient-first)"],
    );
    for (row, &(dataset, bucket)) in cells.chunks_exact(policies.len()).zip(&rows) {
        t.row(vec![
            format!("{} ({})", dataset.name(), bucket.name()),
            pct(row[0].energy_eff),
            pct(row[1].energy_eff),
            pct(row[2].energy_eff),
        ]);
    }
    vec![t]
}

/// SporkE allocation + a specific dispatch policy over a multi-app
/// workload. Apps fan out over the shared executor (each builds its own
/// policy instance); metrics merge in app-index order, bit-identical to
/// the serial loop.
pub fn run_spork_with_dispatch(
    cfg: &SimConfig,
    apps: &[AppTrace],
    policy: DispatchPolicy,
) -> Cell {
    let defaults = PlatformConfig::paper_default();
    let per_app = crate::util::executor::Executor::global().map(apps, 0, |_, app| {
        let mut s = sched::spork::Spork::new(cfg, Objective::energy()).with_dispatch(policy);
        sim::run(app, cfg.clone(), &defaults, &mut s).metrics
    });
    let mut total = Metrics::default();
    for m in &per_app {
        total.merge(m);
    }
    let ideal = IdealBaseline::for_work(total.total_work, &defaults);
    Cell::from_run(&total, &ideal).finish()
}
