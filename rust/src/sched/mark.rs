//! MArk-ideal baseline (§5.1): an idealized re-implementation of MArk
//! [93], the state-of-the-art cost-optimized hybrid scheduler, with the
//! benefit-of-the-doubt oracle the paper grants it ("perfect workload
//! predictions up to two intervals into the future").
//!
//! Key differences from Spork, per the paper's comparison:
//! * **cost-optimized only** — FPGAs (its "accelerators") are allocated
//!   only up to the cost-breakeven utilization; the remainder runs on
//!   on-demand CPUs;
//! * **round-robin dispatch** — evenly spreads requests, which keeps
//!   workers from idling long enough to be reclaimed;
//! * predictive allocation at interval granularity plus reactive CPU
//!   spin-up on the dispatch path (like Spork's burst path).

use super::breakeven::{breakeven_fpga_seconds, Objective};
use super::dispatch::Dispatcher;
use super::oracle::Oracle;
use crate::config::{DispatchPolicy, SimConfig, WorkerKind};
use crate::policy::{Action, Observation, Policy, PolicyView, Target};

pub struct MarkIdeal {
    oracle: Oracle,
    interval: f64,
    dispatcher: Dispatcher,
}

impl MarkIdeal {
    pub fn new(cfg: &SimConfig, trace_oracle_cost: Oracle) -> Self {
        debug_assert!(
            breakeven_fpga_seconds(&cfg.platform, cfg.interval, Objective::cost()).is_finite()
                || trace_oracle_cost.needed.iter().all(|&n| n == 0),
            "cost oracle must be built with the cost objective"
        );
        Self {
            oracle: trace_oracle_cost,
            interval: cfg.interval,
            dispatcher: Dispatcher::new(DispatchPolicy::RoundRobin),
        }
    }
}

impl Policy for MarkIdeal {
    fn name(&self) -> String {
        "mark-ideal".into()
    }

    fn interval(&self) -> f64 {
        self.interval
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &WorkerKind::EFFICIENT_FIRST;
        match obs {
            Observation::Start => {
                // Perfect predictions: the first interval's fleet is warm
                // when the window opens (allocation happened one interval
                // earlier).
                let n0 = self.oracle.needed_at(0).max(self.oracle.needed_at(1));
                out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: n0,
                    prewarmed: true,
                });
            }
            Observation::Tick { index, .. } => {
                // Perfect two-interval lookahead: provision now what the
                // next interval needs (allocation takes one interval).
                let target = self.oracle.needed_at(index + 1);
                let cur = view.allocated(WorkerKind::Fpga);
                if target > cur {
                    out.push(Action::Alloc {
                        kind: WorkerKind::Fpga,
                        n: target - cur,
                        prewarmed: false,
                    });
                } else if cur > target {
                    // Cost-optimized: shed surplus FPGAs immediately rather
                    // than paying occupancy for the idle-timeout window.
                    out.push(Action::Retire {
                        kind: WorkerKind::Fpga,
                        n: cur - target,
                    });
                }
            }
            Observation::Arrival { req } => {
                let to = match self.dispatcher.find(view, &req, KINDS) {
                    Some(w) => Target::Worker(w),
                    None => Target::Fresh(WorkerKind::Cpu),
                };
                out.push(Action::Dispatch { req, to });
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::sim;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    fn run_mark(seed: u64, b: f64) -> sim::RunResult {
        let mut rng = Rng::new(seed);
        let trace = synthetic_app("m", &mut rng, b, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let oracle = Oracle::from_trace(&trace, &cfg, Objective::cost());
        sim::run(
            &trace,
            cfg.clone(),
            &PlatformConfig::paper_default(),
            &mut MarkIdeal::new(&cfg, oracle),
        )
    }

    #[test]
    fn serves_and_meets_deadlines() {
        let r = run_mark(8, 0.6);
        assert!(r.miss_fraction() < 0.01, "misses {}", r.miss_fraction());
        assert!(r.metrics.on_fpga > 0, "should use FPGAs at this load");
        assert!(r.metrics.on_cpu > 0, "round robin spreads to CPUs");
    }

    #[test]
    fn cost_competitive_but_energy_poor() {
        // The paper's core observation: MArk-ideal's cost is decent but
        // its round-robin + cost-only allocation wastes energy vs Spork.
        use crate::sched::breakeven::Objective as Obj;
        use crate::sched::spork::Spork;
        let mut rng = Rng::new(9);
        let trace = synthetic_app("m", &mut rng, 0.65, 600.0, 300.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let oracle = Oracle::from_trace(&trace, &cfg, Obj::cost());
        let rm = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut MarkIdeal::new(&cfg, oracle),
        );
        let rs = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut Spork::new(&cfg, Obj::energy()),
        );
        assert!(
            rs.energy_efficiency() > rm.energy_efficiency(),
            "SporkE {} must beat MArk-ideal {} on energy",
            rs.energy_efficiency(),
            rm.energy_efficiency()
        );
    }
}
