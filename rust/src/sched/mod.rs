//! Schedulers: Spork (all variants) and the paper's baselines — all
//! implementations of the transport-agnostic [`Policy`] trait — plus the
//! factory mapping [`SchedulerKind`] to implementations.
//!
//! The factory is the single source of truth: [`build`] returns the
//! *fitted* policy for every kind (FPGA-dynamic's least-feasible headroom,
//! FPGA-static's least-feasible fleet), so the sim driver and the
//! real-time serving driver can never diverge on what a kind means.

pub mod breakeven;
pub mod cpu_dynamic;
pub mod dispatch;
pub mod fit;
pub mod fpga_dynamic;
pub mod fpga_static;
pub mod mark;
pub mod oracle;
pub mod spork;
pub mod spot;

pub use breakeven::Objective;
pub use fit::{FitBatch, FitEngine, FitPass, FitStats, FIT_HARD_CEILING};
pub use oracle::{Oracle, WorkloadProfile};

use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::policy::Policy;
use crate::scenario::ScenarioConfig;
use crate::sim::{self, RunResult};
use crate::trace::{AppTrace, ArrivalSource};

/// Deadline-miss tolerance of the baselines' fitting searches (paper
/// §5.1: the fitted baselines "meet request deadlines").
pub const FIT_MISS_TOLERANCE: f64 = 0.005;

/// A re-creatable workload stream: calling the factory yields a fresh
/// [`ArrivalSource`] positioned at t = 0. Oracle construction and the
/// §5.1 fitting searches replay the workload several times; with a
/// factory each pass streams in constant memory instead of requiring a
/// materialized trace. Synthetic factories rebuild the source from its
/// `(seed_base, seed)` stream; CSV factories re-open the file.
///
/// `Sync` because the parallel lockstep-fitting batch (`fit.rs` under
/// the bounded executor, DESIGN.md §14) calls the factory from several
/// worker threads at once. The *returned* source is neither `Send` nor
/// `Sync` — each worker creates its own source and consumes it on that
/// same thread, so the stream itself never crosses threads.
pub type MakeSource<'a> = dyn Fn() -> Box<dyn ArrivalSource + 'a> + Sync + 'a;

/// Build the policy for `kind`, fitted to `trace` where the paper requires
/// it. Oracle-assisted baselines (FPGA-static, MArk-ideal, Spork-*-ideal)
/// compute their oracle from `trace`; FPGA-dynamic and FPGA-static run
/// their §5.1 fitting search so every caller gets the same policy
/// `run_scheduler` evaluates.
pub fn build(kind: &SchedulerKind, cfg: &SimConfig, trace: &AppTrace) -> Box<dyn Policy> {
    build_source(kind, cfg, &|| Box::new(trace.source()))
}

/// [`build`] over a re-creatable source stream — constant-memory for
/// every kind (the fitting searches stream each pass).
pub fn build_source(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    make: &MakeSource<'_>,
) -> Box<dyn Policy> {
    match kind {
        SchedulerKind::FpgaStatic => {
            Box::new(fpga_static::fitted_source(make, cfg, FIT_MISS_TOLERANCE))
        }
        SchedulerKind::FpgaDynamic => {
            Box::new(fpga_dynamic::fitted_source(make, cfg, FIT_MISS_TOLERANCE))
        }
        _ => build_unfitted(kind, cfg, &|obj| Oracle::from_source(&mut *make(), cfg, obj)),
    }
}

/// The single copy of the non-fitted kind → (objective, constructor)
/// mapping, shared by the streaming ([`build_source`]) and
/// profile-cached ([`run_scheduler_profile`]) paths — only the oracle
/// *provider* differs between them, so the two paths cannot drift.
fn build_unfitted(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    oracle_of: &dyn Fn(Objective) -> Oracle,
) -> Box<dyn Policy> {
    match kind {
        SchedulerKind::CpuDynamic => Box::new(cpu_dynamic::CpuDynamic::new()),
        SchedulerKind::GreedySpot => Box::new(spot::GreedySpot::new()),
        SchedulerKind::OndemandFallback => Box::new(spot::OndemandFallback::new()),
        SchedulerKind::SporkFallback => Box::new(spot::SporkFallback::new(cfg)),
        SchedulerKind::MarkIdeal => {
            Box::new(mark::MarkIdeal::new(cfg, oracle_of(Objective::cost())))
        }
        SchedulerKind::Spork {
            w_energy,
            w_cost,
            ideal,
        } => {
            let obj = Objective {
                w_energy: *w_energy,
                w_cost: *w_cost,
            };
            if *ideal {
                Box::new(spork::Spork::ideal(cfg, obj, oracle_of(obj)))
            } else {
                Box::new(spork::Spork::new(cfg, obj))
            }
        }
        SchedulerKind::FpgaStatic | SchedulerKind::FpgaDynamic => {
            unreachable!("fitted kinds are built by their §5.1 fitting searches")
        }
    }
}

/// Run one scheduler kind over one app trace through the sim driver. The
/// fitted kinds reuse their fitting search's winning run instead of
/// re-simulating it — byte-identical to running the [`build`]-returned
/// policy (pinned by `factory_and_run_scheduler_agree_on_fitted_kinds`),
/// just without the redundant simulation.
pub fn run_scheduler(
    kind: &SchedulerKind,
    trace: &AppTrace,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
) -> RunResult {
    run_scheduler_source(kind, cfg, defaults, &|| Box::new(trace.source()))
}

/// [`run_scheduler`] over a re-creatable source stream: every pass
/// (oracle construction, fitting iterations, the final run) streams the
/// workload, so memory is bounded by pool size + pending events — the
/// path the million-request bench replays through.
pub fn run_scheduler_source(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    make: &MakeSource<'_>,
) -> RunResult {
    match kind {
        SchedulerKind::FpgaDynamic => {
            fpga_dynamic::fit_source(make, cfg, defaults, FIT_MISS_TOLERANCE).0
        }
        SchedulerKind::FpgaStatic => {
            fpga_static::fit_source(make, cfg, defaults, FIT_MISS_TOLERANCE).0
        }
        _ => {
            let mut policy = build_source(kind, cfg, make);
            sim::run_source(make(), cfg.clone(), defaults, policy.as_mut())
        }
    }
}

/// [`run_scheduler`] against a cached [`WorkloadProfile`] — the sweep
/// engine's path. Bit-identical to [`run_scheduler`] on the profile's
/// trace (pinned by `rust/tests/fit_parity.rs`): the trace is the same
/// materialized arrivals, and every oracle derives from the profile's
/// cached bins through the same breakeven mapping `Oracle::from_source`
/// applies. What changes is only the cost: one workload shared by N
/// scheduler kinds pays synthesis and O(arrivals) binning once, not N
/// times.
pub fn run_scheduler_profile(
    kind: &SchedulerKind,
    profile: &WorkloadProfile,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
) -> RunResult {
    match kind {
        SchedulerKind::FpgaStatic => {
            fpga_static::fit_profile(profile, cfg, defaults, FIT_MISS_TOLERANCE).0
        }
        SchedulerKind::FpgaDynamic => {
            fpga_dynamic::fit_profile(profile, cfg, defaults, FIT_MISS_TOLERANCE).0
        }
        _ => {
            let mut policy =
                build_unfitted(kind, cfg, &|obj| Oracle::from_profile(profile, cfg, obj));
            sim::run_source(Box::new(profile.source()), cfg.clone(), defaults, policy.as_mut())
        }
    }
}

/// [`run_scheduler_source`] under a fault scenario. Fitting (and oracle
/// construction) stays **fault-free** — the paper's §5.1 searches size
/// fleets against the workload, not against adversity — and only the
/// final evaluation run replays the workload with the scenario's
/// [`FaultPlan`](crate::scenario::FaultPlan) attached. With a fault-free
/// scenario this is byte-identical to building the policy and running it
/// plain (pinned by `rust/tests/scenario.rs`).
pub fn run_scheduler_scenario(
    kind: &SchedulerKind,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    make: &MakeSource<'_>,
    scenario: &ScenarioConfig,
    seed_base: u64,
    seed: u64,
) -> RunResult {
    let mut policy = build_source(kind, cfg, make);
    sim::run_source_scenario(
        make(),
        cfg.clone(),
        defaults,
        policy.as_mut(),
        scenario,
        seed_base,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn factory_builds_all_table8_kinds() {
        let mut rng = Rng::new(1);
        let trace = synthetic_app("t", &mut rng, 0.6, 60.0, 50.0, 0.010);
        let cfg = SimConfig::paper_default();
        for kind in SchedulerKind::table8_roster() {
            let s = build(&kind, &cfg, &trace);
            assert_eq!(s.name(), kind.name(), "factory/name mismatch");
        }
    }

    #[test]
    fn factory_builds_the_scenario_roster() {
        let mut rng = Rng::new(1);
        let trace = synthetic_app("t", &mut rng, 0.6, 60.0, 50.0, 0.010);
        let cfg = SimConfig::paper_default();
        for kind in SchedulerKind::scenario_roster() {
            let s = build(&kind, &cfg, &trace);
            assert_eq!(s.name(), kind.name(), "factory/name mismatch");
        }
    }

    #[test]
    fn factory_and_run_scheduler_agree_on_fitted_kinds() {
        // The old factory handed out an *unfitted* FPGA-dynamic while
        // `run_scheduler` fitted it; pin that both paths now produce the
        // same results.
        let mut rng = Rng::new(3);
        let trace = synthetic_app("t", &mut rng, 0.65, 120.0, 80.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        for kind in [SchedulerKind::FpgaDynamic, SchedulerKind::FpgaStatic] {
            let mut via_factory = build(&kind, &cfg, &trace);
            let a = sim::run(&trace, cfg.clone(), &defaults, via_factory.as_mut());
            let b = run_scheduler(&kind, &trace, &cfg, &defaults);
            assert_eq!(
                a.metrics.deadline_misses, b.metrics.deadline_misses,
                "{} diverged",
                kind.name()
            );
            assert_eq!(a.metrics.total_energy(), b.metrics.total_energy());
            assert_eq!(a.metrics.total_cost(), b.metrics.total_cost());
        }
    }

    #[test]
    fn profile_path_matches_trace_path_for_all_kinds() {
        // run_scheduler_profile must be bit-identical to run_scheduler on
        // the profile's trace for the full Table-8 roster — the guarantee
        // that lets the sweep engine share one profile per workload.
        let mut rng = Rng::new(8);
        let trace = synthetic_app("t", &mut rng, 0.65, 120.0, 80.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let profile = WorkloadProfile::from_trace(trace.clone(), cfg.interval);
        for kind in SchedulerKind::table8_roster() {
            let a = run_scheduler(&kind, &trace, &cfg, &defaults);
            let b = run_scheduler_profile(&kind, &profile, &cfg, &defaults);
            assert_eq!(
                a.metrics.deadline_misses, b.metrics.deadline_misses,
                "{} misses diverged",
                kind.name()
            );
            assert_eq!(a.metrics.requests, b.metrics.requests, "{}", kind.name());
            assert_eq!(
                a.metrics.total_energy(),
                b.metrics.total_energy(),
                "{} energy diverged",
                kind.name()
            );
            assert_eq!(
                a.metrics.total_cost(),
                b.metrics.total_cost(),
                "{} cost diverged",
                kind.name()
            );
            assert_eq!(a.metrics.fpga_spinups, b.metrics.fpga_spinups, "{}", kind.name());
        }
    }

    #[test]
    fn all_schedulers_complete_all_requests() {
        let mut rng = Rng::new(2);
        let trace = synthetic_app("t", &mut rng, 0.65, 120.0, 100.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        for kind in SchedulerKind::table8_roster() {
            let r = run_scheduler(&kind, &trace, &cfg, &defaults);
            assert_eq!(
                r.metrics.requests as usize,
                trace.len(),
                "{} dropped requests",
                kind.name()
            );
            assert!(
                r.metrics.total_energy() > 0.0,
                "{} recorded no energy",
                kind.name()
            );
        }
    }
}
