//! Schedulers: Spork (all variants) and the paper's baselines, plus the
//! factory mapping [`SchedulerKind`] to implementations.

pub mod breakeven;
pub mod cpu_dynamic;
pub mod dispatch;
pub mod fpga_dynamic;
pub mod fpga_static;
pub mod mark;
pub mod oracle;
pub mod spork;

pub use breakeven::Objective;
pub use oracle::Oracle;

use crate::config::{PlatformConfig, SchedulerKind, SimConfig};
use crate::sim::{self, RunResult, Scheduler};
use crate::trace::AppTrace;

/// Build a scheduler for `kind`. Oracle-assisted baselines (FPGA-static,
/// MArk-ideal, Spork-*-ideal) compute their oracle from `trace`.
pub fn build(kind: &SchedulerKind, cfg: &SimConfig, trace: &AppTrace) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::CpuDynamic => Box::new(cpu_dynamic::CpuDynamic::new()),
        SchedulerKind::FpgaStatic => {
            let oracle = Oracle::from_trace(trace, cfg, Objective::energy());
            Box::new(fpga_static::FpgaStatic::new(&oracle))
        }
        SchedulerKind::FpgaDynamic => {
            // Unfitted default (headroom = 1x max delta); prefer
            // `run_scheduler`, which fits per the paper.
            let oracle = Oracle::from_trace(trace, cfg, Objective::energy());
            Box::new(fpga_dynamic::FpgaDynamic::new(
                cfg,
                oracle.max_consecutive_delta().max(1),
            ))
        }
        SchedulerKind::MarkIdeal => {
            let oracle = Oracle::from_trace(trace, cfg, Objective::cost());
            Box::new(mark::MarkIdeal::new(cfg, oracle))
        }
        SchedulerKind::Spork {
            w_energy,
            w_cost,
            ideal,
        } => {
            let obj = Objective {
                w_energy: *w_energy,
                w_cost: *w_cost,
            };
            if *ideal {
                let oracle = Oracle::from_trace(trace, cfg, obj);
                Box::new(spork::Spork::ideal(cfg, obj, oracle))
            } else {
                Box::new(spork::Spork::new(cfg, obj))
            }
        }
    }
}

/// Run one scheduler kind over one app trace, handling the baselines'
/// fitting requirements (FPGA-dynamic's least-feasible headroom).
pub fn run_scheduler(
    kind: &SchedulerKind,
    trace: &AppTrace,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
) -> RunResult {
    match kind {
        SchedulerKind::FpgaDynamic => {
            let (r, _k) = fpga_dynamic::fit(trace, cfg, defaults, 0.005);
            r
        }
        SchedulerKind::FpgaStatic => {
            let (r, _fleet) = fpga_static::fit(trace, cfg, defaults, 0.005);
            r
        }
        _ => {
            let mut sched = build(kind, cfg, trace);
            sim::run(trace, cfg.clone(), defaults, sched.as_mut())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn factory_builds_all_table8_kinds() {
        let mut rng = Rng::new(1);
        let trace = synthetic_app("t", &mut rng, 0.6, 60.0, 50.0, 0.010);
        let cfg = SimConfig::paper_default();
        for kind in SchedulerKind::table8_roster() {
            let s = build(&kind, &cfg, &trace);
            assert_eq!(s.name(), kind.name(), "factory/name mismatch");
        }
    }

    #[test]
    fn all_schedulers_complete_all_requests() {
        let mut rng = Rng::new(2);
        let trace = synthetic_app("t", &mut rng, 0.65, 120.0, 100.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        for kind in SchedulerKind::table8_roster() {
            let r = run_scheduler(&kind, &trace, &cfg, &defaults);
            assert_eq!(
                r.metrics.requests as usize,
                trace.len(),
                "{} dropped requests",
                kind.name()
            );
            assert!(
                r.metrics.total_energy() > 0.0,
                "{} recorded no energy",
                kind.name()
            );
        }
    }
}
