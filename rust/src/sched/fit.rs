//! The shared §5.1 fitting-search engines: find the *least feasible*
//! candidate (fleet-size step for FPGA-static, headroom multiple for
//! FPGA-dynamic) without paying one full stream traversal per probe.
//!
//! Feasibility — `miss_fraction() <= tolerance` — is monotone in the
//! candidate index for both searches (more fleet / more headroom never
//! adds misses; pinned by `more_headroom_fewer_misses` and the parity
//! suite). Two engines exploit that, pinned bit-identical to each other
//! and to an uncapped linear scan by `rust/tests/fit_parity.rs`:
//!
//! * **Serial** ([`fit_least_feasible`]) — classic gallop + bisection,
//!   one stream traversal per probe: candidates 0, 1, 2, 4, 8, … until
//!   the first feasible one, then binary search of the bracket. Every
//!   infeasible probe runs with the early-abort miss budget armed
//!   (`sim::run_source_bounded`), so it touches only the trace prefix
//!   needed to *prove* infeasibility. O(log k) traversals. This engine
//!   serves the materialized-profile path ([`fit_profile`]), where
//!   re-traversing the shared `Vec` is nearly free and simulating only
//!   the gallop path is the cheapest possible plan.
//!
//! * **Lockstep** ([`fit_least_feasible_lockstep`]) — the whole gallop
//!   ladder probed as one *batch* through a single traversal of the
//!   shared stream ([`crate::trace::tee`] + `sim::run_sources_lockstep`:
//!   N drivers, each with its own miss budget, stepped within one
//!   arrival of each other), then the bisect bracket swept as a second
//!   batch. ≤ 2 full-trace-equivalent traversals for any fit inside the
//!   first ladder wave — down from O(log k) — which is what matters on
//!   *streaming* paths where every traversal re-synthesizes or re-parses
//!   the arrival stream. The ladder is wave-gated (see
//!   [`LOCKSTEP_WAVES`]): a wave of rungs runs only after the previous
//!   wave proved every rung infeasible, so the engine never simulates
//!   fleets orders of magnitude beyond the fitted candidate just to fill
//!   a batch. When the process-wide executor has permits to spare, a
//!   batch's drivers additionally run *concurrently* over per-candidate
//!   fresh streams instead of a shared tee — bit-identical runs either
//!   way (see [`run_candidate_batch`] and DESIGN.md §14).
//!
//! Both engines return a winning run that needs no re-simulation: a
//! feasible pass never reaches its miss budget, so its bounded run IS
//! the full run, bit for bit.
//!
//! If no candidate is feasible below [`FIT_HARD_CEILING`] the search
//! fails loudly (stderr warning + `FitStats::feasible == false`) and
//! returns a *full* run of the ceiling candidate, preserving the old
//! "best effort so far" return contract without hiding the failure.

use super::MakeSource;
use crate::config::SimConfig;
use crate::policy::Policy;
use crate::sim::{self, BoundedRun, RunResult};
use crate::trace::{tee, ArrivalSource, KnownLen};
use crate::util::executor::Executor;
use std::time::Instant;

/// Generous upper bound on the candidate index (the old searches capped
/// at 8). The gallop ladder reaches it in ~13 cheap aborted probes; a
/// workload that is still infeasible at 4096 fleet steps / headroom
/// multiples cannot be served at any plausible scale and the caller
/// needs to hear about it, not simulate an even larger fleet.
pub const FIT_HARD_CEILING: u32 = 4_096;

/// Which fitting engine a search runs on. Streaming entry points default
/// to [`FitEngine::Lockstep`] (each traversal re-synthesizes the
/// stream); the materialized-profile path uses [`FitEngine::Serial`]
/// (re-traversal is a `Vec` iteration, and the gallop simulates the
/// fewest candidates). The two are pinned bit-identical on fitted
/// candidate, winning run, and feasibility by `tests/fit_parity.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitEngine {
    Lockstep,
    Serial,
}

/// The lockstep gallop ladder, split into waves. Each wave is one
/// shared-stream traversal probing its rungs concurrently; a wave runs
/// only if every rung of the previous wave aborted (which, by
/// monotonicity, proves the fit lies above it). Splitting caps how far
/// past the fitted candidate a batch simulates: probing 4096 fleet
/// steps in the same pass that fits at 2 would cost orders of magnitude
/// more sim work (and worker memory) than the serial gallop ever pays.
/// The first wave spans every candidate the bench workloads fit within,
/// so the common search is one wave + one bracket sweep = ≤ 2
/// traversals.
const LOCKSTEP_WAVES: &[&[u32]] = &[
    &[0, 1, 2, 4, 8, 16],
    &[32, 64, 128, 256, 512],
    &[1024, 2048, FIT_HARD_CEILING],
];

/// Max candidates per bracket-sweep traversal: bounds concurrent
/// `SimState`s (each holds a candidate-sized worker pool). Brackets
/// wider than this — only reachable above ladder rung 64 — sweep in
/// ascending chunks, stopping at the first chunk containing a feasible
/// candidate; all-aborted chunks cost only their abort prefixes.
const LOCKSTEP_MAX_BATCH: usize = 64;

/// One candidate's simulation pass within a fitting search.
#[derive(Clone, Debug)]
pub struct FitPass {
    /// Candidate index probed (fleet step j / headroom multiple k).
    pub candidate: u32,
    /// Arrivals simulated for this candidate (the full trace unless
    /// aborted). In a lockstep batch this is the per-candidate count —
    /// candidates share the stream traversal but not the simulation.
    pub arrivals: u64,
    /// Whether the pass stopped at its miss budget (⟹ infeasible).
    pub aborted: bool,
    pub feasible: bool,
}

/// One traversal of the arrival stream: a batch of candidates probed in
/// lockstep (the serial engine emits single-candidate batches). Wall
/// time lives here, not on [`FitPass`] — candidates in a lockstep batch
/// share one traversal, so attributing the batch's wall clock to each
/// candidate would overcount it N-fold.
#[derive(Clone, Debug)]
pub struct FitBatch {
    pub passes: Vec<FitPass>,
    /// Wall time of the whole batch (one shared traversal).
    pub wall_seconds: f64,
}

impl FitBatch {
    /// Arrivals the shared stream had to yield for this batch: the
    /// deepest consumer's count. Aborted candidates drop out early, but
    /// the stream advances with whichever consumer goes furthest.
    pub fn stream_arrivals(&self) -> u64 {
        self.passes.iter().map(|p| p.arrivals).max().unwrap_or(0)
    }
}

/// What a fitting search cost and decided — surfaced by the `spork
/// bench-sim --fit` axis and by `SPORK_FIT_VERBOSE=1`.
#[derive(Clone, Debug)]
pub struct FitStats {
    pub label: String,
    /// Which engine ran the search: "lockstep" or "serial".
    pub engine: &'static str,
    /// The fitted candidate index (least feasible, or the hard ceiling
    /// when `feasible` is false).
    pub fitted_candidate: u32,
    /// False only when no candidate up to [`FIT_HARD_CEILING`] met the
    /// tolerance — the loud-failure path.
    pub feasible: bool,
    /// The workload's exact request count (`Oracle::total_requests`,
    /// which every full pass replays — never an aborted prefix; pinned
    /// by `infeasible_everywhere_reports_exact_total_arrivals`).
    pub total_arrivals: u64,
    /// Stream traversals, in order: one batch per traversal.
    pub batches: Vec<FitBatch>,
}

impl FitStats {
    /// All candidate passes across all batches, in probe order.
    pub fn passes(&self) -> impl Iterator<Item = &FitPass> {
        self.batches.iter().flat_map(|b| b.passes.iter())
    }

    pub fn pass_count(&self) -> usize {
        self.batches.iter().map(|b| b.passes.len()).sum()
    }

    pub fn aborted_passes(&self) -> usize {
        self.passes().filter(|p| p.aborted).count()
    }

    /// Stream traversals in units of one full pass: each batch costs the
    /// deepest consumer's arrival count once (the traversal is shared),
    /// summed over batches. For the serial engine's single-candidate
    /// batches this equals the per-pass arrival sum — the pre-lockstep
    /// metric. This is the cost `--assert-fit-passes` caps: what the
    /// search paid in stream synthesis/parsing.
    pub fn full_trace_equivalents(&self) -> f64 {
        if self.total_arrivals == 0 {
            return self.batches.len() as f64;
        }
        self.batches
            .iter()
            .map(|b| b.stream_arrivals() as f64)
            .sum::<f64>()
            / self.total_arrivals as f64
    }

    /// Total *simulated* arrivals across all candidates, in full-pass
    /// units — the sim-CPU cost, which lockstep batching does not reduce
    /// (every candidate still simulates its own prefix).
    pub fn simulated_trace_equivalents(&self) -> f64 {
        if self.total_arrivals == 0 {
            return self.pass_count() as f64;
        }
        self.passes().map(|p| p.arrivals as f64).sum::<f64>() / self.total_arrivals as f64
    }

    fn log_verbose(&self) {
        if std::env::var_os("SPORK_FIT_VERBOSE").is_some() {
            eprintln!(
                "[fit] {} ({}): fitted candidate {}{} after {} passes in {} batches \
                 ({} aborted early; {:.2} stream traversals, {:.2} simulated \
                 full-trace equivalents over {} arrivals)",
                self.label,
                self.engine,
                self.fitted_candidate,
                if self.feasible { "" } else { " (INFEASIBLE)" },
                self.pass_count(),
                self.batches.len(),
                self.aborted_passes(),
                self.full_trace_equivalents(),
                self.simulated_trace_equivalents(),
                self.total_arrivals,
            );
        }
    }
}

/// One candidate pass of a fitting search — the single copy of the
/// pass-running protocol both searches share: wrap a fresh stream from
/// `make` with the oracle-counted exact `total` (so the miss budget can
/// arm even on generator sources), then run bounded (early abort) or
/// unbounded (the ceiling-failure full rerun). Results are normalized
/// against `cfg.platform`; callers rebase the ideal baseline.
pub(crate) fn run_candidate_pass(
    make: &MakeSource<'_>,
    total: u64,
    cfg: &SimConfig,
    miss_tolerance: f64,
    bounded: bool,
    policy: &mut dyn Policy,
) -> BoundedRun {
    let src = Box::new(KnownLen::new(make(), total));
    if bounded {
        sim::run_source_bounded(src, cfg.clone(), &cfg.platform, policy, miss_tolerance)
    } else {
        BoundedRun {
            result: sim::run_source(src, cfg.clone(), &cfg.platform, policy),
            aborted: false,
        }
    }
}

/// One traversal probing a whole candidate batch. Two bit-identical
/// execution plans, chosen by permit availability on the process-wide
/// executor (DESIGN.md §14):
///
/// * **Parallel** — when the executor grants at least one extra permit,
///   each candidate gets its own *fresh* stream from `make` (exact
///   count `total` attached, so every driver's miss budget arms
///   identically) and its own bounded driver, run concurrently via
///   [`Executor::try_map`]. Each driver executes exactly the serial
///   [`run_candidate_pass`] protocol — a `MakeSource` is a pure
///   factory, so every candidate sees the identical stream and aborts
///   at the identical arrival — and the batch's stream-traversal cost
///   accounting is unchanged: [`FitBatch::stream_arrivals`] is the max
///   over candidates under either plan (the traversal's critical path,
///   now paid concurrently instead of once up front).
/// * **Tee-lockstep** (the serial fallback) — a single fresh stream
///   fanned out through [`tee`], one policy and one driver per
///   candidate, stepped within one arrival of each other by
///   `sim::run_sources_lockstep`, synthesis paid once. This is the plan
///   whenever no extra permit is available (budget 1, or an outer
///   fan-out holds the pool). A *shared* tee is not an option under
///   concurrency: its bounded spread cap would deadlock any batch with
///   more candidates than granted threads, so the parallel plan trades
///   one traversal's worth of redundant synthesis for wall clock.
///
/// With `bounded == false` (the ceiling-failure rerun, always a single
/// candidate) this falls back to serial unbounded passes.
pub(crate) fn run_candidate_batch(
    make: &MakeSource<'_>,
    total: u64,
    cfg: &SimConfig,
    miss_tolerance: f64,
    bounded: bool,
    candidates: &[u32],
    policy_of: &(dyn Fn(u32) -> Box<dyn Policy> + Sync),
) -> Vec<BoundedRun> {
    run_candidate_batch_with(
        Executor::global(),
        make,
        total,
        cfg,
        miss_tolerance,
        bounded,
        candidates,
        policy_of,
    )
}

/// [`run_candidate_batch`] against an explicit executor — the seam the
/// three-plan parity test pins deterministically (a local executor's
/// permit pool is not subject to whatever else the process runs).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_candidate_batch_with(
    exec: &Executor,
    make: &MakeSource<'_>,
    total: u64,
    cfg: &SimConfig,
    miss_tolerance: f64,
    bounded: bool,
    candidates: &[u32],
    policy_of: &(dyn Fn(u32) -> Box<dyn Policy> + Sync),
) -> Vec<BoundedRun> {
    if !bounded {
        return candidates
            .iter()
            .map(|&c| {
                let mut policy = policy_of(c);
                run_candidate_pass(make, total, cfg, miss_tolerance, false, policy.as_mut())
            })
            .collect();
    }
    // Parallel plan: independent bounded drivers over fresh streams.
    if let Some(runs) = exec.try_map(candidates, |_, &c| {
        let mut policy = policy_of(c);
        run_candidate_pass(make, total, cfg, miss_tolerance, true, policy.as_mut())
    }) {
        return runs;
    }
    // Serial plan: one shared stream teed across the batch.
    let stream = Box::new(KnownLen::new(make(), total));
    let sources: Vec<Box<dyn ArrivalSource + '_>> = tee(stream, candidates.len())
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn ArrivalSource + '_>)
        .collect();
    let mut policies: Vec<Box<dyn Policy>> =
        candidates.iter().map(|&c| policy_of(c)).collect();
    sim::run_sources_lockstep(sources, cfg, &cfg.platform, &mut policies, miss_tolerance)
}

/// Find the least feasible candidate by serial gallop + bisection.
///
/// `run_pass(candidate, bounded)` simulates one candidate; when `bounded`
/// it must arm the early-abort budget for `miss_tolerance` (the engine
/// passes `bounded == false` only for the ceiling-failure full rerun).
/// `total_arrivals` is the workload's exact request count (from the
/// oracle pass). Returns the winning run — always a complete pass — the
/// fitted candidate, and the per-pass cost accounting.
pub(crate) fn fit_least_feasible(
    label: &str,
    total_arrivals: u64,
    miss_tolerance: f64,
    run_pass: &mut dyn FnMut(u32, bool) -> BoundedRun,
) -> (RunResult, u32, FitStats) {
    let mut stats = FitStats {
        label: label.to_string(),
        engine: "serial",
        fitted_candidate: 0,
        feasible: false,
        total_arrivals,
        batches: Vec::new(),
    };
    let mut probe = |cand: u32, bounded: bool, stats: &mut FitStats| -> (RunResult, bool) {
        let t0 = Instant::now();
        let run = run_pass(cand, bounded);
        // With the budget armed, `!aborted` already implies feasibility;
        // the explicit miss_fraction check keeps unbounded passes (no
        // len_hint, ceiling rerun) on the same predicate.
        let feasible = !run.aborted && run.result.miss_fraction() <= miss_tolerance;
        stats.batches.push(FitBatch {
            passes: vec![FitPass {
                candidate: cand,
                arrivals: run.result.metrics.requests,
                aborted: run.aborted,
                feasible,
            }],
            wall_seconds: t0.elapsed().as_secs_f64(),
        });
        (run.result, feasible)
    };

    // Candidate 0 first — identical to the old scan's first probe, and
    // the common case (most workloads fit without extra headroom).
    let (r0, f0) = probe(0, true, &mut stats);
    if f0 {
        stats.fitted_candidate = 0;
        stats.feasible = true;
        stats.log_verbose();
        return (r0, 0, stats);
    }

    // Gallop for the first feasible candidate: every miss is a cheap
    // aborted prefix, and the bracket doubles each step.
    let mut lo = 0u32; // greatest known-infeasible candidate
    let mut hi = 1u32;
    let mut best: RunResult;
    loop {
        let (r, feasible) = probe(hi, true, &mut stats);
        if feasible {
            best = r;
            break;
        }
        if hi >= FIT_HARD_CEILING {
            // Loud failure: the old scan silently returned its last
            // infeasible run. Keep that return shape (callers get a full
            // run to report) but mark and announce the failure, and
            // re-run unbounded so the returned metrics cover the whole
            // trace rather than the aborted prefix.
            eprintln!(
                "warning: [fit] {label}: no feasible candidate up to the hard \
                 ceiling {FIT_HARD_CEILING}; returning the ceiling candidate's \
                 run marked infeasible"
            );
            let (rf, _) = probe(hi, false, &mut stats);
            stats.fitted_candidate = hi;
            stats.feasible = false;
            stats.log_verbose();
            return (rf, hi, stats);
        }
        lo = hi;
        hi = hi.saturating_mul(2).min(FIT_HARD_CEILING);
    }

    // Bisect (lo, hi]: lo is infeasible, hi is feasible with `best` its
    // full run. Invariant holds until hi - lo == 1, when hi is least.
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        let (r, feasible) = probe(mid, true, &mut stats);
        if feasible {
            hi = mid;
            best = r;
        } else {
            lo = mid;
        }
    }
    stats.fitted_candidate = hi;
    stats.feasible = true;
    stats.log_verbose();
    debug_assert_eq!(
        best.metrics.requests, total_arrivals,
        "a winning pass must cover the whole workload"
    );
    (best, hi, stats)
}

/// Find the least feasible candidate with lockstep candidate batches —
/// ≤ 2 full-trace-equivalent stream traversals for any fit inside the
/// first ladder wave (one for the ladder, one for the bracket sweep).
///
/// `run_batch(candidates, bounded)` simulates the batch through one
/// shared stream traversal and returns one [`BoundedRun`] per candidate
/// in order ([`run_candidate_batch`] is the production implementation);
/// `bounded == false` only ever carries a single candidate (the
/// ceiling-failure full rerun).
///
/// The plan, licensed by monotone feasibility:
///
/// 1. **Ladder waves** ([`LOCKSTEP_WAVES`]): probe the gallop ladder —
///    the exact rungs the serial engine would visit — one wave per
///    traversal, stopping at the first wave containing a feasible rung
///    `hi`. Every rung before `hi` aborted, so the fit is in
///    `(below, hi]` where `below` is the last rung before `hi`.
/// 2. **Bracket sweep**: probe `below+1 .. hi` ascending in one more
///    traversal (chunked at [`LOCKSTEP_MAX_BATCH`]); the first feasible
///    candidate is the least feasible overall. If the whole interior
///    aborts, `hi` itself is the fit — its full run is already in hand.
///
/// All-rungs-aborted falls through to the same loud ceiling failure as
/// the serial engine (unbounded full rerun of the ceiling candidate,
/// `FitStats::feasible == false`).
pub(crate) fn fit_least_feasible_lockstep(
    label: &str,
    total_arrivals: u64,
    miss_tolerance: f64,
    run_batch: &mut dyn FnMut(&[u32], bool) -> Vec<BoundedRun>,
) -> (RunResult, u32, FitStats) {
    let mut stats = FitStats {
        label: label.to_string(),
        engine: "lockstep",
        fitted_candidate: 0,
        feasible: false,
        total_arrivals,
        batches: Vec::new(),
    };
    let mut probe =
        |cands: &[u32], bounded: bool, stats: &mut FitStats| -> Vec<(RunResult, bool)> {
            let t0 = Instant::now();
            let runs = run_batch(cands, bounded);
            assert_eq!(
                runs.len(),
                cands.len(),
                "lockstep batch runner must return one run per candidate"
            );
            let mut passes = Vec::with_capacity(cands.len());
            let mut out = Vec::with_capacity(cands.len());
            for (&cand, run) in cands.iter().zip(runs) {
                let feasible = !run.aborted && run.result.miss_fraction() <= miss_tolerance;
                passes.push(FitPass {
                    candidate: cand,
                    arrivals: run.result.metrics.requests,
                    aborted: run.aborted,
                    feasible,
                });
                out.push((run.result, feasible));
            }
            stats.batches.push(FitBatch {
                passes,
                wall_seconds: t0.elapsed().as_secs_f64(),
            });
            out
        };

    // Phase 1: wave-gated ladder. `lo` tracks the greatest candidate
    // proven infeasible by a completed wave.
    let mut lo: Option<u32> = None;
    let mut bracket: Option<(Option<u32>, u32, RunResult)> = None;
    'waves: for wave in LOCKSTEP_WAVES {
        let results = probe(wave, true, &mut stats);
        for (i, (r, feasible)) in results.into_iter().enumerate() {
            if feasible {
                let below = if i > 0 { Some(wave[i - 1]) } else { lo };
                bracket = Some((below, wave[i], r));
                break 'waves;
            }
        }
        lo = Some(*wave.last().expect("ladder waves are non-empty"));
    }

    let Some((below, hi, hi_run)) = bracket else {
        // Same loud failure as the serial engine: full unbounded rerun
        // of the ceiling candidate, marked infeasible.
        eprintln!(
            "warning: [fit] {label}: no feasible candidate up to the hard \
             ceiling {FIT_HARD_CEILING}; returning the ceiling candidate's \
             run marked infeasible"
        );
        let mut runs = probe(&[FIT_HARD_CEILING], false, &mut stats);
        stats.fitted_candidate = FIT_HARD_CEILING;
        stats.feasible = false;
        stats.log_verbose();
        return (runs.remove(0).0, FIT_HARD_CEILING, stats);
    };

    // Phase 2: sweep the bracket interior ascending. First feasible
    // candidate = least feasible overall; a fully-aborted interior means
    // `hi` is the fit.
    let mut fitted = hi;
    let mut best = hi_run;
    if let Some(below) = below {
        let mut start = below + 1;
        'chunks: while start < hi {
            let end = hi.min(start + LOCKSTEP_MAX_BATCH as u32);
            let cands: Vec<u32> = (start..end).collect();
            let results = probe(&cands, true, &mut stats);
            for (i, (r, feasible)) in results.into_iter().enumerate() {
                if feasible {
                    fitted = cands[i];
                    best = r;
                    break 'chunks;
                }
            }
            start = end;
        }
    }
    stats.fitted_candidate = fitted;
    stats.feasible = true;
    stats.log_verbose();
    debug_assert_eq!(
        best.metrics.requests, total_arrivals,
        "a winning pass must cover the whole workload"
    );
    (best, fitted, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{IdealBaseline, Metrics};
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Synthetic single-candidate pass: candidates below `least_feasible`
    /// "miss" everything (and abort when bounded), the rest are clean.
    fn fake_pass(least_feasible: u32, total: u64, cand: u32, bounded: bool) -> BoundedRun {
        let feasible = cand >= least_feasible;
        let mut m = Metrics::default();
        if feasible {
            m.requests = total;
            m.deadline_misses = 0;
        } else if bounded {
            // Aborted after a small prefix.
            m.requests = (total / 10).max(1);
            m.deadline_misses = m.requests;
        } else {
            m.requests = total;
            m.deadline_misses = total;
        }
        // Distinguish runs so the winner can be identified.
        m.total_work = cand as f64 + 1.0;
        BoundedRun {
            result: RunResult {
                scheduler: "fake".into(),
                metrics: m,
                ideal: IdealBaseline {
                    energy: 0.0,
                    cost: 0.0,
                },
            },
            aborted: bounded && !feasible,
        }
    }

    fn runner(
        least_feasible: u32,
        total: u64,
        log: Rc<RefCell<Vec<(u32, bool)>>>,
    ) -> impl FnMut(u32, bool) -> BoundedRun {
        move |cand, bounded| {
            log.borrow_mut().push((cand, bounded));
            fake_pass(least_feasible, total, cand, bounded)
        }
    }

    fn batch_runner(
        least_feasible: u32,
        total: u64,
        log: Rc<RefCell<Vec<(Vec<u32>, bool)>>>,
    ) -> impl FnMut(&[u32], bool) -> Vec<BoundedRun> {
        move |cands, bounded| {
            log.borrow_mut().push((cands.to_vec(), bounded));
            cands
                .iter()
                .map(|&c| fake_pass(least_feasible, total, c, bounded))
                .collect()
        }
    }

    fn fit(least: u32) -> (RunResult, u32, FitStats) {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut r = runner(least, 1000, log);
        fit_least_feasible("test", 1000, 0.005, &mut r)
    }

    fn fit_lockstep(least: u32) -> (RunResult, u32, FitStats) {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut r = batch_runner(least, 1000, log);
        fit_least_feasible_lockstep("test", 1000, 0.005, &mut r)
    }

    #[test]
    fn finds_least_feasible_for_every_target() {
        for least in [0u32, 1, 2, 3, 5, 8, 9, 13, 27, 100] {
            let (run, fitted, stats) = fit(least);
            assert_eq!(fitted, least, "least-feasible candidate");
            assert!(stats.feasible);
            assert_eq!(stats.engine, "serial");
            // Winning run is the full pass of the fitted candidate.
            assert_eq!(run.metrics.total_work, least as f64 + 1.0);
            assert_eq!(run.metrics.requests, 1000);
            // O(log k) full passes: only feasible probes stream the whole
            // trace, and there are at most ~2·log2(k)+2 of them.
            let full = stats.passes().filter(|p| !p.aborted).count();
            let bound = 2 * (32 - least.max(1).leading_zeros()) as usize + 2;
            assert!(full <= bound, "least={least}: {full} full passes > {bound}");
            // Serial batches are all single-candidate.
            assert!(stats.batches.iter().all(|b| b.passes.len() == 1));
        }
    }

    #[test]
    fn lockstep_finds_least_feasible_for_every_target() {
        for least in [0u32, 1, 2, 3, 5, 8, 9, 13, 16, 17, 27, 100, 500, 3000, 4096] {
            let (run, fitted, stats) = fit_lockstep(least);
            assert_eq!(fitted, least, "least-feasible candidate");
            assert!(stats.feasible);
            assert_eq!(stats.engine, "lockstep");
            assert_eq!(run.metrics.total_work, least as f64 + 1.0);
            assert_eq!(run.metrics.requests, 1000);
            // Serial/lockstep agree on the fitted candidate.
            assert_eq!(fit(least).1, fitted);
            // Stream-traversal economy: ladder waves cost abort prefixes
            // (0.1 here) until the wave containing the fit (1.0), plus a
            // bracket sweep whose aborted chunks cost 0.1 and whose
            // final chunk streams fully. Fits inside the first wave —
            // the shape the bench workloads pin — take ≤ 2 traversals.
            let fte = stats.full_trace_equivalents();
            if least <= 16 {
                assert!(fte <= 2.0 + 1e-9, "least={least}: {fte} traversals");
                assert!(stats.batches.len() <= 2, "least={least}");
            }
            assert!(fte <= 3.0 + 1e-9, "least={least}: {fte} traversals");
        }
    }

    #[test]
    fn lockstep_probes_waves_then_bracket() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut r = batch_runner(27, 1000, log.clone());
        let (_, fitted, _) = fit_least_feasible_lockstep("test", 1000, 0.005, &mut r);
        assert_eq!(fitted, 27);
        let log = log.borrow();
        // Wave 1 all-aborts (fit is 27 > 16), wave 2's first rung 32 is
        // feasible, bracket interior is 17..=31 in one chunk.
        assert_eq!(log.len(), 3);
        assert_eq!(log[0], (vec![0, 1, 2, 4, 8, 16], true));
        assert_eq!(log[1], (vec![32, 64, 128, 256, 512], true));
        assert_eq!(log[2], ((17..32).collect::<Vec<u32>>(), true));
    }

    #[test]
    fn pass_count_beats_linear_scan_for_large_fits() {
        let (_, fitted, stats) = fit(100);
        assert_eq!(fitted, 100);
        // Linear scan would pay 101 full passes; gallop+bisect stays
        // logarithmic and aborted probes stream only a prefix.
        assert!(stats.pass_count() <= 16, "passes {}", stats.pass_count());
        assert!(stats.full_trace_equivalents() < 20.0);
        assert!(stats.aborted_passes() > 0);
    }

    #[test]
    fn ceiling_failure_is_loud_and_marked() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut r = runner(u32::MAX, 1000, log.clone());
        let (run, fitted, stats) = fit_least_feasible("test", 1000, 0.005, &mut r);
        assert_eq!(fitted, FIT_HARD_CEILING);
        assert!(!stats.feasible, "must be marked infeasible");
        // The returned run is a full (unbounded) pass, not an aborted
        // prefix — total_arrivals stays the exact workload count even
        // though every bounded pass aborted.
        assert_eq!(run.metrics.requests, 1000);
        assert_eq!(stats.total_arrivals, 1000);
        let last = log.borrow().last().cloned().unwrap();
        assert_eq!(last, (FIT_HARD_CEILING, false));
    }

    #[test]
    fn lockstep_ceiling_failure_is_loud_and_marked() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut r = batch_runner(u32::MAX, 1000, log.clone());
        let (run, fitted, stats) = fit_least_feasible_lockstep("test", 1000, 0.005, &mut r);
        assert_eq!(fitted, FIT_HARD_CEILING);
        assert!(!stats.feasible, "must be marked infeasible");
        assert_eq!(run.metrics.requests, 1000);
        assert_eq!(stats.total_arrivals, 1000);
        // Three all-aborted waves, then the single-candidate unbounded
        // rerun of the ceiling.
        let log = log.borrow();
        assert_eq!(log.len(), LOCKSTEP_WAVES.len() + 1);
        assert_eq!(log.last().cloned().unwrap(), (vec![FIT_HARD_CEILING], false));
        // The failed search still cost ~1 traversal (abort prefixes plus
        // the full rerun), not one per rung.
        assert!(stats.full_trace_equivalents() <= 1.5);
    }

    #[test]
    fn ladder_waves_cover_the_serial_gallop_exactly() {
        // The lockstep ladder must visit the same rungs the serial
        // gallop does (0, then powers of two up to the ceiling), so the
        // two engines prove infeasibility from identical probe sets.
        let flat: Vec<u32> = LOCKSTEP_WAVES.iter().flat_map(|w| w.iter().copied()).collect();
        let mut serial = vec![0u32, 1];
        let mut hi = 1u32;
        while hi < FIT_HARD_CEILING {
            hi = hi.saturating_mul(2).min(FIT_HARD_CEILING);
            serial.push(hi);
        }
        assert_eq!(flat, serial);
        assert!(flat.windows(2).all(|w| w[0] < w[1]), "ladder must ascend");
    }

    /// The three batch plans — parallel fresh-stream drivers, the
    /// tee-lockstep fallback, and plain serial passes — must be bit-
    /// identical on a real workload. Local executors pin the plan
    /// choice deterministically: `Executor::new(8)` guarantees permits
    /// (parallel plan), `Executor::new(1)` guarantees none (tee plan),
    /// independent of whatever the process-wide pool is doing.
    #[test]
    fn candidate_batch_plans_are_bit_identical() {
        use crate::config::SimConfig;
        use crate::sched::fpga_static::FpgaStatic;
        use crate::trace::synthetic_source;
        use crate::util::rng::Rng;

        let cfg = SimConfig::paper_default();
        let make = || -> Box<dyn ArrivalSource> {
            Box::new(synthetic_source("fit", Rng::new(7), 0.7, 30.0, 400.0, 0.010, 60.0))
        };
        let mut total = 0u64;
        {
            let mut s = make();
            while s.next_arrival().is_some() {
                total += 1;
            }
        }
        assert!(total > 100, "workload too small to exercise the batch");
        // Exponential fleet ladder: 1 FPGA drowns in this workload's
        // bursts (aborted pass), 32 is far over-provisioned (full pass).
        let policy_of =
            |c: u32| -> Box<dyn Policy> { Box::new(FpgaStatic::with_fleet(1 << c)) };
        let candidates: Vec<u32> = (0..6).collect();
        let tol = 0.005;
        let parallel = run_candidate_batch_with(
            &Executor::new(8),
            &make,
            total,
            &cfg,
            tol,
            true,
            &candidates,
            &policy_of,
        );
        let teed = run_candidate_batch_with(
            &Executor::new(1),
            &make,
            total,
            &cfg,
            tol,
            true,
            &candidates,
            &policy_of,
        );
        let serial: Vec<BoundedRun> = candidates
            .iter()
            .map(|&c| {
                let mut p = policy_of(c);
                run_candidate_pass(&make, total, &cfg, tol, true, p.as_mut())
            })
            .collect();
        assert_eq!(parallel.len(), candidates.len());
        for (i, a) in parallel.iter().enumerate() {
            for (plan, r) in [("tee", &teed[i]), ("serial", &serial[i])] {
                assert_eq!(a.aborted, r.aborted, "candidate {i} vs {plan}");
                let (ma, mr) = (&a.result.metrics, &r.result.metrics);
                assert_eq!(ma.requests, mr.requests, "candidate {i} vs {plan}");
                assert_eq!(
                    ma.deadline_misses, mr.deadline_misses,
                    "candidate {i} vs {plan}"
                );
                assert_eq!(
                    ma.total_work.to_bits(),
                    mr.total_work.to_bits(),
                    "candidate {i} vs {plan}"
                );
                assert_eq!(
                    ma.total_energy().to_bits(),
                    mr.total_energy().to_bits(),
                    "candidate {i} vs {plan}"
                );
                assert_eq!(
                    ma.total_cost().to_bits(),
                    mr.total_cost().to_bits(),
                    "candidate {i} vs {plan}"
                );
            }
        }
        // A meaningful batch exercises both outcomes: small fleets abort
        // at their miss budget, large ones run the full trace.
        assert!(parallel.iter().any(|r| r.aborted), "no aborting candidate");
        assert!(parallel.iter().any(|r| !r.aborted), "no feasible candidate");
    }

    #[test]
    fn batch_stream_cost_is_the_deepest_consumer() {
        let b = FitBatch {
            passes: vec![
                FitPass { candidate: 0, arrivals: 100, aborted: true, feasible: false },
                FitPass { candidate: 1, arrivals: 1000, aborted: false, feasible: true },
                FitPass { candidate: 2, arrivals: 1000, aborted: false, feasible: true },
            ],
            wall_seconds: 0.5,
        };
        assert_eq!(b.stream_arrivals(), 1000);
        let stats = FitStats {
            label: "t".into(),
            engine: "lockstep",
            fitted_candidate: 1,
            feasible: true,
            total_arrivals: 1000,
            batches: vec![b],
        };
        // One shared traversal, even though 2100 arrivals were simulated.
        assert!((stats.full_trace_equivalents() - 1.0).abs() < 1e-12);
        assert!((stats.simulated_trace_equivalents() - 2.1).abs() < 1e-12);
        assert_eq!(stats.pass_count(), 3);
        assert_eq!(stats.aborted_passes(), 1);
    }
}
