//! The shared §5.1 fitting-search engine: find the *least feasible*
//! candidate (fleet-size step for FPGA-static, headroom multiple for
//! FPGA-dynamic) in O(log k) full-trace passes instead of a linear scan.
//!
//! Feasibility — `miss_fraction() <= tolerance` — is monotone in the
//! candidate index for both searches (more fleet / more headroom never
//! adds misses; pinned by `more_headroom_fewer_misses` and the parity
//! suite), which licenses the classic two-phase search:
//!
//! 1. **Gallop**: probe candidates 0, 1, 2, 4, 8, … until the first
//!    feasible one. Each infeasible probe runs with the early-abort miss
//!    budget armed (`sim::run_source_bounded`), so it touches only the
//!    trace prefix needed to *prove* infeasibility.
//! 2. **Bisect**: binary-search the (last-infeasible, first-feasible]
//!    bracket for the least feasible candidate. Under monotonicity this
//!    is exactly the candidate the old `for k in 0..=8` scan returned —
//!    same fitted policy, same winning run, bit for bit — but without the
//!    scan's hard cap of 8 (the cap silently returned an *infeasible* fit
//!    when the search ran off its end).
//!
//! The winning run needs no re-simulation: a feasible pass never reaches
//! its miss budget, so its bounded run IS the full run.
//!
//! If no candidate is feasible below [`FIT_HARD_CEILING`] the search
//! fails loudly (stderr warning + `FitStats::feasible == false`) and
//! returns a *full* run of the ceiling candidate, preserving the old
//! "best effort so far" return contract without hiding the failure.

use super::MakeSource;
use crate::config::SimConfig;
use crate::policy::Policy;
use crate::sim::{self, BoundedRun, RunResult};
use crate::trace::KnownLen;
use std::time::Instant;

/// Generous upper bound on the candidate index (the old searches capped
/// at 8). Galloping reaches it in ~13 cheap aborted probes; a workload
/// that is still infeasible at 4096 fleet steps / headroom multiples
/// cannot be served at any plausible scale and the caller needs to hear
/// about it, not simulate an even larger fleet.
pub const FIT_HARD_CEILING: u32 = 4_096;

/// One simulation pass of a fitting search.
#[derive(Clone, Debug)]
pub struct FitPass {
    /// Candidate index probed (fleet step j / headroom multiple k).
    pub candidate: u32,
    /// Arrivals actually simulated (the full trace unless aborted).
    pub arrivals: u64,
    /// Whether the pass stopped at its miss budget (⟹ infeasible).
    pub aborted: bool,
    pub feasible: bool,
    pub wall_seconds: f64,
}

/// What a fitting search cost and decided — surfaced by the `spork
/// bench-sim --fit` axis and by `SPORK_FIT_VERBOSE=1`.
#[derive(Clone, Debug)]
pub struct FitStats {
    pub label: String,
    /// The fitted candidate index (least feasible, or the hard ceiling
    /// when `feasible` is false).
    pub fitted_candidate: u32,
    /// False only when no candidate up to [`FIT_HARD_CEILING`] met the
    /// tolerance — the loud-failure path.
    pub feasible: bool,
    /// Arrivals in one full pass (the workload's exact request count).
    pub total_arrivals: u64,
    pub passes: Vec<FitPass>,
}

impl FitStats {
    pub fn pass_count(&self) -> usize {
        self.passes.len()
    }

    pub fn aborted_passes(&self) -> usize {
        self.passes.iter().filter(|p| p.aborted).count()
    }

    /// Total simulated arrivals across all passes, in units of one full
    /// pass — the search's whole-trace-equivalent cost (the linear scan
    /// paid ~1.0 per candidate probed).
    pub fn full_trace_equivalents(&self) -> f64 {
        if self.total_arrivals == 0 {
            return self.passes.len() as f64;
        }
        self.passes.iter().map(|p| p.arrivals as f64).sum::<f64>()
            / self.total_arrivals as f64
    }

    fn log_verbose(&self) {
        if std::env::var_os("SPORK_FIT_VERBOSE").is_some() {
            eprintln!(
                "[fit] {}: fitted candidate {}{} after {} passes \
                 ({} aborted early; {:.2} full-trace equivalents over {} arrivals)",
                self.label,
                self.fitted_candidate,
                if self.feasible { "" } else { " (INFEASIBLE)" },
                self.pass_count(),
                self.aborted_passes(),
                self.full_trace_equivalents(),
                self.total_arrivals,
            );
        }
    }
}

/// One candidate pass of a fitting search — the single copy of the
/// pass-running protocol both searches share: wrap a fresh stream from
/// `make` with the oracle-counted exact `total` (so the miss budget can
/// arm even on generator sources), then run bounded (early abort) or
/// unbounded (the ceiling-failure full rerun). Results are normalized
/// against `cfg.platform`; callers rebase the ideal baseline.
pub(crate) fn run_candidate_pass(
    make: &MakeSource<'_>,
    total: u64,
    cfg: &SimConfig,
    miss_tolerance: f64,
    bounded: bool,
    policy: &mut dyn Policy,
) -> BoundedRun {
    let src = Box::new(KnownLen::new(make(), total));
    if bounded {
        sim::run_source_bounded(src, cfg.clone(), &cfg.platform, policy, miss_tolerance)
    } else {
        BoundedRun {
            result: sim::run_source(src, cfg.clone(), &cfg.platform, policy),
            aborted: false,
        }
    }
}

/// Find the least feasible candidate by gallop + bisection.
///
/// `run_pass(candidate, bounded)` simulates one candidate; when `bounded`
/// it must arm the early-abort budget for `miss_tolerance` (the engine
/// passes `bounded == false` only for the ceiling-failure full rerun).
/// `total_arrivals` is the workload's exact request count (from the
/// oracle pass). Returns the winning run — always a complete pass — the
/// fitted candidate, and the per-pass cost accounting.
pub(crate) fn fit_least_feasible(
    label: &str,
    total_arrivals: u64,
    miss_tolerance: f64,
    run_pass: &mut dyn FnMut(u32, bool) -> BoundedRun,
) -> (RunResult, u32, FitStats) {
    let mut stats = FitStats {
        label: label.to_string(),
        fitted_candidate: 0,
        feasible: false,
        total_arrivals,
        passes: Vec::new(),
    };
    let mut probe = |cand: u32, bounded: bool, stats: &mut FitStats| -> (RunResult, bool) {
        let t0 = Instant::now();
        let run = run_pass(cand, bounded);
        // With the budget armed, `!aborted` already implies feasibility;
        // the explicit miss_fraction check keeps unbounded passes (no
        // len_hint, ceiling rerun) on the same predicate.
        let feasible = !run.aborted && run.result.miss_fraction() <= miss_tolerance;
        stats.passes.push(FitPass {
            candidate: cand,
            arrivals: run.result.metrics.requests,
            aborted: run.aborted,
            feasible,
            wall_seconds: t0.elapsed().as_secs_f64(),
        });
        (run.result, feasible)
    };

    // Candidate 0 first — identical to the old scan's first probe, and
    // the common case (most workloads fit without extra headroom).
    let (r0, f0) = probe(0, true, &mut stats);
    if f0 {
        stats.fitted_candidate = 0;
        stats.feasible = true;
        stats.log_verbose();
        return (r0, 0, stats);
    }

    // Gallop for the first feasible candidate: every miss is a cheap
    // aborted prefix, and the bracket doubles each step.
    let mut lo = 0u32; // greatest known-infeasible candidate
    let mut hi = 1u32;
    let mut best: RunResult;
    loop {
        let (r, feasible) = probe(hi, true, &mut stats);
        if feasible {
            best = r;
            break;
        }
        if hi >= FIT_HARD_CEILING {
            // Loud failure: the old scan silently returned its last
            // infeasible run. Keep that return shape (callers get a full
            // run to report) but mark and announce the failure, and
            // re-run unbounded so the returned metrics cover the whole
            // trace rather than the aborted prefix.
            eprintln!(
                "warning: [fit] {label}: no feasible candidate up to the hard \
                 ceiling {FIT_HARD_CEILING}; returning the ceiling candidate's \
                 run marked infeasible"
            );
            let (rf, _) = probe(hi, false, &mut stats);
            stats.fitted_candidate = hi;
            stats.feasible = false;
            stats.log_verbose();
            return (rf, hi, stats);
        }
        lo = hi;
        hi = hi.saturating_mul(2).min(FIT_HARD_CEILING);
    }

    // Bisect (lo, hi]: lo is infeasible, hi is feasible with `best` its
    // full run. Invariant holds until hi - lo == 1, when hi is least.
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        let (r, feasible) = probe(mid, true, &mut stats);
        if feasible {
            hi = mid;
            best = r;
        } else {
            lo = mid;
        }
    }
    stats.fitted_candidate = hi;
    stats.feasible = true;
    stats.log_verbose();
    (best, hi, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{IdealBaseline, Metrics};

    /// Synthetic pass runner: candidates below `least_feasible` "miss"
    /// everything (and abort when bounded), the rest are clean.
    fn runner(
        least_feasible: u32,
        total: u64,
        log: std::rc::Rc<std::cell::RefCell<Vec<(u32, bool)>>>,
    ) -> impl FnMut(u32, bool) -> BoundedRun {
        move |cand, bounded| {
            log.borrow_mut().push((cand, bounded));
            let feasible = cand >= least_feasible;
            let mut m = Metrics::default();
            if feasible {
                m.requests = total;
                m.deadline_misses = 0;
            } else if bounded {
                // Aborted after a small prefix.
                m.requests = (total / 10).max(1);
                m.deadline_misses = m.requests;
            } else {
                m.requests = total;
                m.deadline_misses = total;
            }
            // Distinguish runs so the winner can be identified.
            m.total_work = cand as f64 + 1.0;
            BoundedRun {
                result: RunResult {
                    scheduler: "fake".into(),
                    metrics: m,
                    ideal: IdealBaseline {
                        energy: 0.0,
                        cost: 0.0,
                    },
                },
                aborted: bounded && !feasible,
            }
        }
    }

    fn fit(least: u32) -> (RunResult, u32, FitStats) {
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut r = runner(least, 1000, log);
        fit_least_feasible("test", 1000, 0.005, &mut r)
    }

    #[test]
    fn finds_least_feasible_for_every_target() {
        for least in [0u32, 1, 2, 3, 5, 8, 9, 13, 27, 100] {
            let (run, fitted, stats) = fit(least);
            assert_eq!(fitted, least, "least-feasible candidate");
            assert!(stats.feasible);
            // Winning run is the full pass of the fitted candidate.
            assert_eq!(run.metrics.total_work, least as f64 + 1.0);
            assert_eq!(run.metrics.requests, 1000);
            // O(log k) full passes: only feasible probes stream the whole
            // trace, and there are at most ~2·log2(k)+2 of them.
            let full = stats.passes.iter().filter(|p| !p.aborted).count();
            let bound = 2 * (32 - least.max(1).leading_zeros()) as usize + 2;
            assert!(full <= bound, "least={least}: {full} full passes > {bound}");
        }
    }

    #[test]
    fn pass_count_beats_linear_scan_for_large_fits() {
        let (_, fitted, stats) = fit(100);
        assert_eq!(fitted, 100);
        // Linear scan would pay 101 full passes; gallop+bisect stays
        // logarithmic and aborted probes stream only a prefix.
        assert!(stats.pass_count() <= 16, "passes {}", stats.pass_count());
        assert!(stats.full_trace_equivalents() < 20.0);
        assert!(stats.aborted_passes() > 0);
    }

    #[test]
    fn ceiling_failure_is_loud_and_marked() {
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut r = runner(u32::MAX, 1000, log.clone());
        let (run, fitted, stats) = fit_least_feasible("test", 1000, 0.005, &mut r);
        assert_eq!(fitted, FIT_HARD_CEILING);
        assert!(!stats.feasible, "must be marked infeasible");
        // The returned run is a full (unbounded) pass, not an aborted
        // prefix.
        assert_eq!(run.metrics.requests, 1000);
        let last = log.borrow().last().copied().unwrap();
        assert_eq!(last, (FIT_HARD_CEILING, false));
    }
}
