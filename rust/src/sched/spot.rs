//! Spot-market baselines and the Spork fallback wrapper — the policies
//! the scenario experiments compare under preemptible (spot) capacity.
//!
//! * **GreedySpot** — tessera-style: chase the cheap kind unconditionally.
//!   Every request (fresh or retried) goes to the spot FPGA pool; the
//!   policy never hedges, so it pays the full preemption churn.
//! * **OndemandFallback** — prefer spot for fresh arrivals (efficient-
//!   first over FPGA then CPU, allocating a fresh spot FPGA when no live
//!   worker is feasible) but route *retries* — requests whose worker was
//!   preempted or failed — to on-demand CPU capacity, trading money for
//!   a stop to the kill-retry loop.
//! * **SporkFallback** — Spork's full energy-objective machinery for
//!   everything, except retries which go straight to on-demand CPUs, as
//!   OndemandFallback does. Shows how much of Spork's advantage survives
//!   adversity when paired with the obvious hedge.
//!
//! All three see faults exactly the way every other policy does — through
//! [`Observation::Preempted`] and re-offered arrivals with `attempt > 0`
//! — so scenario comparisons measure routing decisions, not privileged
//! information.
//!
//! None of these policies bound retries themselves: how many attempts a
//! request gets is the attached pack's `ScenarioConfig::retry_budget`
//! (default [`crate::config::DEFAULT_RETRY_BUDGET`], validated against
//! [`crate::config::MAX_RETRY_BUDGET`]), enforced by the sim driver's
//! kill path and mirrored by serve recovery — one budget, one source.

use super::breakeven::Objective;
use super::dispatch::Dispatcher;
use super::spork::Spork;
use crate::config::{DispatchPolicy, SimConfig, WorkerKind};
use crate::policy::{Action, Observation, Policy, PolicyView, Request, Target};

/// Where retries land under the fallback policies: the on-demand
/// (non-spot, fast-spin-up) CPU pool.
const FALLBACK: WorkerKind = WorkerKind::Cpu;

fn dispatch_to(
    dispatcher: &mut Dispatcher,
    view: &dyn PolicyView,
    req: Request,
    kinds: &[WorkerKind],
    fresh: WorkerKind,
) -> Target {
    match dispatcher.find(view, &req, kinds) {
        Some(id) => Target::Worker(id),
        None => Target::Fresh(fresh),
    }
}

/// Tessera-style greedy spot chaser: everything onto the spot FPGAs.
pub struct GreedySpot {
    dispatcher: Dispatcher,
}

impl GreedySpot {
    pub fn new() -> Self {
        Self {
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

impl Default for GreedySpot {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for GreedySpot {
    fn name(&self) -> String {
        "greedy-spot".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        if let Observation::Arrival { req } = obs {
            let to = dispatch_to(
                &mut self.dispatcher,
                view,
                req,
                &[WorkerKind::Fpga],
                WorkerKind::Fpga,
            );
            // Greedy even on retries: the same spot pool, the same risk.
            if req.attempt > 0 {
                out.push(Action::Redispatch { req, to });
            } else {
                out.push(Action::Dispatch { req, to });
            }
        }
    }
}

/// Prefer spot, but retries go to on-demand CPUs.
pub struct OndemandFallback {
    dispatcher: Dispatcher,
}

impl OndemandFallback {
    pub fn new() -> Self {
        Self {
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

impl Default for OndemandFallback {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for OndemandFallback {
    fn name(&self) -> String {
        "ondemand-fallback".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        if let Observation::Arrival { req } = obs {
            if req.attempt > 0 {
                // Already burned once — pay for reliable capacity.
                let to = dispatch_to(&mut self.dispatcher, view, req, &[FALLBACK], FALLBACK);
                out.push(Action::Redispatch { req, to });
            } else {
                // Fresh arrivals chase the cheap capacity: reuse any
                // feasible worker (FPGA first), else grow the spot pool.
                let to = dispatch_to(
                    &mut self.dispatcher,
                    view,
                    req,
                    &WorkerKind::EFFICIENT_FIRST,
                    WorkerKind::Fpga,
                );
                out.push(Action::Dispatch { req, to });
            }
        }
    }
}

/// Spork (energy objective) with the on-demand retry hedge bolted on:
/// fresh arrivals and all allocation decisions are Spork's own; retries
/// bypass it and land on on-demand CPUs.
pub struct SporkFallback {
    inner: Spork,
    fallback: Dispatcher,
}

impl SporkFallback {
    pub fn new(cfg: &SimConfig) -> Self {
        Self {
            inner: Spork::new(cfg, Objective::energy()),
            fallback: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

impl Policy for SporkFallback {
    fn name(&self) -> String {
        "spork-fallback".into()
    }

    fn interval(&self) -> f64 {
        self.inner.interval()
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        match obs {
            Observation::Arrival { req } if req.attempt > 0 => {
                let to = dispatch_to(&mut self.fallback, view, req, &[FALLBACK], FALLBACK);
                out.push(Action::Redispatch { req, to });
            }
            _ => self.inner.observe(obs, view, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, SimConfig};
    use crate::scenario::ScenarioConfig;
    use crate::sim;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    fn workload() -> crate::trace::AppTrace {
        let mut rng = Rng::new(11);
        synthetic_app("spot", &mut rng, 0.6, 60.0, 40.0, 0.010)
    }

    #[test]
    fn policies_serve_fault_free_runs_completely() {
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let trace = workload();
        let mut policies: Vec<Box<dyn Policy>> = vec![
            Box::new(GreedySpot::new()),
            Box::new(OndemandFallback::new()),
            Box::new(SporkFallback::new(&cfg)),
        ];
        for p in policies.iter_mut() {
            let r = sim::run(&trace, cfg.clone(), &defaults, p.as_mut());
            assert_eq!(
                r.metrics.requests as usize,
                trace.len(),
                "{} dropped requests",
                p.name()
            );
            assert_eq!(r.metrics.requests, r.metrics.completions, "{}", p.name());
        }
    }

    #[test]
    fn fallback_routes_retries_to_cpu_under_severe_faults() {
        // Under the severe pack, OndemandFallback must land every retried
        // request on CPUs (visible as on-going CPU work even though fresh
        // arrivals prefer FPGAs), and conservation must hold.
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let trace = workload();
        let scen = ScenarioConfig::severe();
        let mut policy = OndemandFallback::new();
        let r = sim::run_source_scenario(
            Box::new(trace.source()),
            cfg,
            &defaults,
            &mut policy,
            &scen,
            1,
            0,
        );
        let m = &r.metrics;
        assert!(m.preemptions > 0, "severe pack must preempt this workload");
        assert_eq!(
            m.requests,
            m.completions + m.abandoned,
            "arrival conservation under faults"
        );
        assert!(
            m.redispatches > 0 || m.abandoned > 0,
            "kills must orphan some in-flight work"
        );
    }

    #[test]
    fn greedy_spot_keeps_retries_on_spot() {
        // GreedySpot never touches CPUs: all work (fresh and retried)
        // stays on the FPGA pool.
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let trace = workload();
        let scen = ScenarioConfig::severe();
        let mut policy = GreedySpot::new();
        let r = sim::run_source_scenario(
            Box::new(trace.source()),
            cfg,
            &defaults,
            &mut policy,
            &scen,
            1,
            0,
        );
        assert_eq!(r.metrics.on_cpu, 0);
        assert_eq!(r.metrics.cpu_spinups, 0);
        assert_eq!(
            r.metrics.requests,
            r.metrics.completions + r.metrics.abandoned
        );
    }
}
