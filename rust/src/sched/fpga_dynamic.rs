//! FPGA-dynamic baseline (§5.1): an FPGA-only reactive scheduler that
//! tracks load with a fixed excess headroom, like traditional autoscaling
//! [4, 27, 72]. The headroom is an integer multiple `k` of the maximum
//! consecutive-interval change in needed workers; per the paper, each
//! trace uses the least `k` that meets request deadlines — [`fitted`]
//! searches for it, and the `sched::build` factory always hands out the
//! fitted policy so no caller can observe an unfitted variant.

use super::breakeven::{
    breakeven_fpga_seconds, lambda_fpga_seconds, needed_fpgas, Objective,
};
use super::dispatch::Dispatcher;
use super::fit::{self, FitEngine, FitStats};
use super::oracle::{Oracle, WorkloadProfile};
use super::MakeSource;
use crate::config::{DispatchPolicy, PlatformConfig, SimConfig, WorkerKind};
use crate::policy::{
    earliest_finishing, Action, Observation, Policy, PolicyView, Target,
};
use crate::sim::{IdealBaseline, RunResult};
use crate::trace::AppTrace;

pub struct FpgaDynamic {
    headroom: u32,
    interval: f64,
    speedup: f64,
    breakeven: f64,
    dispatcher: Dispatcher,
    /// Current allocation target (needed + headroom); idle workers within
    /// the target are kept alive so the headroom stands continuously.
    target: u32,
}

impl FpgaDynamic {
    pub fn new(cfg: &SimConfig, headroom: u32) -> Self {
        Self {
            headroom,
            interval: cfg.interval,
            speedup: cfg.platform.fpga.speedup,
            breakeven: breakeven_fpga_seconds(&cfg.platform, cfg.interval, Objective::energy()),
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
            target: headroom.max(1),
        }
    }
}

impl Policy for FpgaDynamic {
    fn name(&self) -> String {
        "fpga-dynamic".into()
    }

    fn interval(&self) -> f64 {
        self.interval
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &[WorkerKind::Fpga];
        match obs {
            Observation::Start => {
                // Reactive autoscaler over an already-running deployment:
                // the initial headroom is warm when the window opens.
                out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: self.headroom.max(1),
                    prewarmed: true,
                });
            }
            Observation::Tick {
                cpu_work,
                fpga_work,
                ..
            } => {
                debug_assert_eq!(cpu_work, 0.0, "FPGA-only platform saw CPU work");
                let lambda = lambda_fpga_seconds(cpu_work, fpga_work, self.speedup);
                let needed = needed_fpgas(lambda, self.interval, self.breakeven);
                self.target = needed + self.headroom;
                let cur = view.allocated(WorkerKind::Fpga);
                if self.target > cur {
                    out.push(Action::Alloc {
                        kind: WorkerKind::Fpga,
                        n: self.target - cur,
                        prewarmed: false,
                    });
                }
                // Excess above the target drains via the idle timeout.
            }
            Observation::IdleExpired { worker } => {
                // Maintain the standing headroom: don't let reclamation
                // pull the fleet below the target while the trace is live.
                if view.trace_live() && view.allocated(WorkerKind::Fpga) <= self.target {
                    out.push(Action::KeepAlive { worker });
                }
            }
            Observation::Arrival { req } => {
                let to = match self.dispatcher.find(view, &req, KINDS) {
                    Some(w) => Target::Worker(w),
                    None => {
                        // Allocation happens only at interval boundaries
                        // (FPGA spin-ups are useless within a 100ms-deadline
                        // burst); best-effort onto the earliest-finishing
                        // worker — misses here are exactly what the headroom
                        // fit eliminates. If the fleet fully drained (deep
                        // lull), re-seed one.
                        match earliest_finishing(view, WorkerKind::Fpga) {
                            Some(w) => Target::Worker(w),
                            None => Target::Fresh(WorkerKind::Fpga),
                        }
                    }
                };
                out.push(Action::Dispatch { req, to });
            }
            _ => {}
        }
    }
}

/// The §5.1 fitting search: least headroom multiple `k` (of the oracle's
/// max consecutive delta) whose run meets deadlines within
/// `miss_tolerance`. Returns the winning run (normalized against
/// `cfg.platform`), the headroom, k, and the pass accounting.
///
/// Feasibility is monotone in the headroom (pinned by
/// `more_headroom_fewer_misses`), so the search needs O(log k)
/// feasibility probes, with every infeasible probe early-aborting at its
/// exact miss budget (the oracle pass counts the workload's arrivals).
/// The `engine` picks how probes map onto stream traversals:
/// [`FitEngine::Lockstep`] batches the gallop ladder and the bisect
/// bracket through shared traversals (≤ 2 full-trace equivalents for
/// ordinary fits — the default for streaming entry points);
/// [`FitEngine::Serial`] probes one candidate per traversal (the
/// materialized-profile path).
fn search(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    miss_tolerance: f64,
    engine: FitEngine,
) -> (RunResult, u32, u32, FitStats) {
    let oracle = Oracle::from_source(&mut *make(), cfg, Objective::energy());
    search_with_oracle(&oracle, make, cfg, miss_tolerance, engine)
}

/// [`search`] with a precomputed oracle (the profile-cached sweep path).
fn search_with_oracle(
    oracle: &Oracle,
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    miss_tolerance: f64,
    engine: FitEngine,
) -> (RunResult, u32, u32, FitStats) {
    let delta = oracle.max_consecutive_delta().max(1);
    let total = oracle.total_requests;
    let (r, k, stats) = match engine {
        FitEngine::Serial => {
            fit::fit_least_feasible("fpga-dynamic", total, miss_tolerance, &mut |k, bounded| {
                let mut policy = FpgaDynamic::new(cfg, k.saturating_mul(delta));
                fit::run_candidate_pass(make, total, cfg, miss_tolerance, bounded, &mut policy)
            })
        }
        FitEngine::Lockstep => fit::fit_least_feasible_lockstep(
            "fpga-dynamic",
            total,
            miss_tolerance,
            &mut |cands, bounded| {
                fit::run_candidate_batch(make, total, cfg, miss_tolerance, bounded, cands, &|k| {
                    Box::new(FpgaDynamic::new(cfg, k.saturating_mul(delta)))
                })
            },
        ),
    };
    (r, k.saturating_mul(delta), k, stats)
}

/// Least feasible headroom and its multiple k.
pub fn fit_headroom(trace: &AppTrace, cfg: &SimConfig, miss_tolerance: f64) -> (u32, u32) {
    let (_, headroom, k, _stats) = search(
        &|| Box::new(trace.source()),
        cfg,
        miss_tolerance,
        FitEngine::Lockstep,
    );
    (headroom, k)
}

/// The fitted policy (paper §5.1: "FPGA-dynamic allocates the least
/// headroom that meets request deadlines based on an integer multiple of
/// the maximum difference in known request rates between consecutive
/// intervals").
pub fn fitted(trace: &AppTrace, cfg: &SimConfig, miss_tolerance: f64) -> FpgaDynamic {
    let (headroom, _k) = fit_headroom(trace, cfg, miss_tolerance);
    FpgaDynamic::new(cfg, headroom)
}

/// [`fitted`] over a re-creatable source stream (each search pass
/// streams; constant memory in trace length).
pub fn fitted_source(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    miss_tolerance: f64,
) -> FpgaDynamic {
    let (_, headroom, _k, _stats) = search(make, cfg, miss_tolerance, FitEngine::Lockstep);
    FpgaDynamic::new(cfg, headroom)
}

/// Fit and run: the search's best run plus the fitted multiple k. The
/// ideal baseline is rebased onto `defaults` — identical to re-running
/// the fitted configuration (metrics never depend on the baseline), but
/// without the extra simulation.
pub fn fit(
    trace: &AppTrace,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    fit_source(&|| Box::new(trace.source()), cfg, defaults, miss_tolerance)
}

/// [`fit`] over a re-creatable source stream.
pub fn fit_source(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    let (r, k, _stats) = fit_source_stats(make, cfg, defaults, miss_tolerance);
    (r, k)
}

/// [`fit_source`] that also surfaces the search's pass accounting (the
/// `spork bench-sim --fit` axis).
pub fn fit_source_stats(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32, FitStats) {
    fit_source_stats_with(FitEngine::Lockstep, make, cfg, defaults, miss_tolerance)
}

/// [`fit_source_stats`] with an explicit engine choice (parity tests and
/// the bench's lockstep-vs-serial comparison; production callers take the
/// default).
pub fn fit_source_stats_with(
    engine: FitEngine,
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32, FitStats) {
    let (mut r, _headroom, k, stats) = search(make, cfg, miss_tolerance, engine);
    r.ideal = IdealBaseline::for_work(r.metrics.total_work, defaults);
    (r, k, stats)
}

/// [`fit`] against a cached [`WorkloadProfile`]: the oracle derives from
/// the profile's bins (no arrival streaming) and every pass replays the
/// shared materialized trace — re-traversal is a `Vec` iteration, so the
/// serial engine (fewest simulated candidates) wins here. Bit-identical
/// to [`fit`] on the profile's trace.
pub fn fit_profile(
    profile: &WorkloadProfile,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    let oracle = Oracle::from_profile(profile, cfg, Objective::energy());
    let (mut r, _headroom, k, _stats) = search_with_oracle(
        &oracle,
        &|| Box::new(profile.source()),
        cfg,
        miss_tolerance,
        FitEngine::Serial,
    );
    r.ideal = IdealBaseline::for_work(r.metrics.total_work, defaults);
    (r, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn fit_finds_feasible_headroom() {
        let mut rng = Rng::new(5);
        let trace = synthetic_app("fd", &mut rng, 0.6, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let (r, _k) = fit(&trace, &cfg, &PlatformConfig::paper_default(), 0.01);
        assert!(r.miss_fraction() <= 0.05, "misses {}", r.miss_fraction());
        assert_eq!(r.metrics.on_cpu, 0);
    }

    #[test]
    fn fitted_policy_reproduces_fit_run() {
        // The factory path (fitted policy, fresh run) must be bit-identical
        // to the fit search's best run — the divergence the old
        // build/run_scheduler split allowed.
        let mut rng = Rng::new(12);
        let trace = synthetic_app("fd", &mut rng, 0.65, 200.0, 150.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let (r, _k) = fit(&trace, &cfg, &defaults, 0.005);
        let mut p = fitted(&trace, &cfg, 0.005);
        let r2 = sim::run(&trace, cfg.clone(), &defaults, &mut p);
        assert_eq!(r.metrics.deadline_misses, r2.metrics.deadline_misses);
        assert_eq!(r.metrics.fpga_spinups, r2.metrics.fpga_spinups);
        assert_eq!(r.metrics.total_energy(), r2.metrics.total_energy());
        assert_eq!(r.metrics.total_cost(), r2.metrics.total_cost());
    }

    #[test]
    fn more_headroom_fewer_misses() {
        let mut rng = Rng::new(6);
        let trace = synthetic_app("fd", &mut rng, 0.7, 300.0, 300.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let r0 = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut FpgaDynamic::new(&cfg, 0),
        );
        let r8 = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut FpgaDynamic::new(&cfg, 30),
        );
        assert!(
            r8.miss_fraction() <= r0.miss_fraction(),
            "headroom should not hurt: {} vs {}",
            r8.miss_fraction(),
            r0.miss_fraction()
        );
        // (No cost assertion: zero headroom triggers reactive spin-up
        // storms that can cost *more* than a standing headroom.)
    }
}
