//! FPGA-dynamic baseline (§5.1): an FPGA-only reactive scheduler that
//! tracks load with a fixed excess headroom, like traditional autoscaling
//! [4, 27, 72]. The headroom is an integer multiple `k` of the maximum
//! consecutive-interval change in needed workers; per the paper, each
//! trace uses the least `k` that meets request deadlines — [`fit`]
//! searches for it.

use super::breakeven::{
    breakeven_fpga_seconds, lambda_fpga_seconds, needed_fpgas, Objective,
};
use super::dispatch::Dispatcher;
use super::oracle::Oracle;
use crate::config::{DispatchPolicy, PlatformConfig, SimConfig, WorkerKind};
use crate::sim::{self, Request, RunResult, Scheduler, SimState, WorkerId};
use crate::trace::AppTrace;

pub struct FpgaDynamic {
    headroom: u32,
    interval: f64,
    speedup: f64,
    breakeven: f64,
    dispatcher: Dispatcher,
    /// Current allocation target (needed + headroom); idle workers within
    /// the target are kept alive so the headroom stands continuously.
    target: u32,
}

impl FpgaDynamic {
    pub fn new(cfg: &SimConfig, headroom: u32) -> Self {
        Self {
            headroom,
            interval: cfg.interval,
            speedup: cfg.platform.fpga.speedup,
            breakeven: breakeven_fpga_seconds(&cfg.platform, cfg.interval, Objective::energy()),
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
            target: headroom.max(1),
        }
    }
}

impl Scheduler for FpgaDynamic {
    fn name(&self) -> String {
        "fpga-dynamic".into()
    }

    fn interval(&self) -> f64 {
        self.interval
    }

    fn on_start(&mut self, sim: &mut SimState) {
        // Reactive autoscaler over an already-running deployment: the
        // initial headroom is warm when the window opens.
        sim.alloc_prewarmed(WorkerKind::Fpga, self.headroom.max(1));
    }

    fn on_tick(&mut self, sim: &mut SimState) {
        let (cpu_work, fpga_work) = sim.take_interval_work();
        debug_assert_eq!(cpu_work, 0.0, "FPGA-only platform saw CPU work");
        let lambda = lambda_fpga_seconds(cpu_work, fpga_work, self.speedup);
        let needed = needed_fpgas(lambda, self.interval, self.breakeven);
        self.target = needed + self.headroom;
        let cur = sim.allocated(WorkerKind::Fpga);
        if self.target > cur {
            sim.alloc_n(WorkerKind::Fpga, self.target - cur);
        }
        // Excess above the target drains via the idle timeout.
    }

    fn keep_alive(&self, _worker: WorkerId, sim: &SimState) -> bool {
        // Maintain the standing headroom: don't let reclamation pull the
        // fleet below the current target while the trace is live.
        sim.trace_live() && sim.allocated(WorkerKind::Fpga) <= self.target
    }

    fn on_request(&mut self, req: Request, sim: &mut SimState) {
        const KINDS: &[WorkerKind] = &[WorkerKind::Fpga];
        match self.dispatcher.find(sim, &req, KINDS) {
            Some(w) => {
                sim.dispatch(req, w);
            }
            None => {
                // Allocation happens only at interval boundaries (FPGA
                // spin-ups are useless within a 100ms-deadline burst);
                // best-effort onto the earliest-finishing worker — misses
                // here are exactly what the headroom fit eliminates.
                let best: Option<WorkerId> = sim
                    .pool
                    .iter_kind(WorkerKind::Fpga)
                    .filter(|w| w.accepting())
                    .min_by(|a, b| a.busy_until.partial_cmp(&b.busy_until).unwrap())
                    .map(|w| w.id);
                match best {
                    Some(w) => {
                        sim.dispatch(req, w);
                    }
                    None => {
                        // Fleet fully drained (deep lull): re-seed one.
                        let w = sim
                            .alloc(WorkerKind::Fpga)
                            .expect("FPGA cap exhausted with empty pool");
                        sim.dispatch(req, w);
                    }
                }
            }
        }
    }
}

/// Paper §5.1: "FPGA-dynamic allocates the least headroom that meets
/// request deadlines based on an integer multiple of the maximum
/// difference in known request rates between consecutive intervals."
/// Returns the best run and the fitted multiple k.
pub fn fit(
    trace: &AppTrace,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    let oracle = Oracle::from_trace(trace, cfg, Objective::energy());
    let delta = oracle.max_consecutive_delta().max(1);
    let mut best: Option<(RunResult, u32)> = None;
    for k in 0..=8u32 {
        let headroom = k * delta;
        let mut sched = FpgaDynamic::new(cfg, headroom);
        let r = sim::run(trace, cfg.clone(), defaults, &mut sched);
        let miss = r.miss_fraction();
        best = Some((r, k));
        if miss <= miss_tolerance {
            break;
        }
    }
    let (r, k) = best.unwrap();
    (r, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn fit_finds_feasible_headroom() {
        let mut rng = Rng::new(5);
        let trace = synthetic_app("fd", &mut rng, 0.6, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let (r, _k) = fit(&trace, &cfg, &PlatformConfig::paper_default(), 0.01);
        assert!(r.miss_fraction() <= 0.05, "misses {}", r.miss_fraction());
        assert_eq!(r.metrics.on_cpu, 0);
    }

    #[test]
    fn more_headroom_fewer_misses() {
        let mut rng = Rng::new(6);
        let trace = synthetic_app("fd", &mut rng, 0.7, 300.0, 300.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let r0 = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut FpgaDynamic::new(&cfg, 0),
        );
        let r8 = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut FpgaDynamic::new(&cfg, 30),
        );
        assert!(
            r8.miss_fraction() <= r0.miss_fraction(),
            "headroom should not hurt: {} vs {}",
            r8.miss_fraction(),
            r0.miss_fraction()
        );
        // (No cost assertion: zero headroom triggers reactive spin-up
        // storms that can cost *more* than a standing headroom.)
    }
}
