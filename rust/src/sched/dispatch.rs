//! Request dispatch policies (paper Table 9 ablation):
//!
//! * **Efficient-first** — Spork's Alg 3 `FindAvailableWorker`: try worker
//!   kinds in efficiency order (FPGA, then CPU); within a kind prefer
//!   (1) busiest workers, (2) most-recently-idle workers, (3) spinning-up
//!   workers with the most queued load — always subject to the deadline
//!   check. Packing onto the busiest workers lets the others drain and be
//!   reclaimed at their idle timeout.
//! * **Index packing** — AutoScale [27] extended naively to hybrid pools:
//!   busiest-first over *all* workers regardless of kind (the paper notes
//!   it "often dispatches to busy but inefficient CPU workers over idle
//!   FPGAs").
//! * **Round robin** — MArk [93]: rotate over allocated workers ("evenly
//!   distributes requests ... rarely lets workers idle").
//!
//! All policies fall back to `None` when no worker can meet the deadline;
//! the caller then spins up a fresh CPU (Alg 3 line 6).
//!
//! Every preference class is an *extremal query over a deadline
//! feasibility prefix*: a worker can meet the deadline iff
//! `busy_until.max(now) <= bound` with `bound = deadline - service_time`,
//! which is downward-closed in `busy_until`. The dispatcher therefore
//! asks the [`PolicyView`]'s indexed queries (answered in O(log n) off
//! the pool's ordered indexes under both drivers) instead of scanning
//! the fleet per arrival; round robin cursors the live index directly
//! and allocates nothing. Custom views fall back to the trait's
//! reference scans — decision parity between the two paths is pinned by
//! `rust/tests/dispatch_parity.rs`.

use crate::config::{DispatchPolicy, WorkerKind};
use crate::policy::{PolicyView, Request, WorkerId};

/// Stateful dispatcher (round robin needs a cursor).
#[derive(Clone, Debug)]
pub struct Dispatcher {
    pub policy: DispatchPolicy,
    /// Round-robin cursor: kind and id of the last dispatched worker.
    /// Probing resumes at the next live id after it (wrapping), so the
    /// rotation survives workers joining and leaving between arrivals.
    rr_last: Option<(WorkerKind, WorkerId)>,
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Self {
        Self { policy, rr_last: None }
    }

    /// Find a worker for `req` per the policy, restricted to `kinds` (the
    /// homogeneous baselines pass a single kind).
    pub fn find(
        &mut self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        match self.policy {
            DispatchPolicy::EfficientFirst => self.efficient_first(view, req, kinds),
            DispatchPolicy::IndexPacking => self.index_packing(view, req, kinds),
            DispatchPolicy::RoundRobin => self.round_robin(view, req, kinds),
        }
    }

    /// Alg 3: kinds in efficiency order; per kind the β (busy, decreasing
    /// load), ι (idle, increasing idle duration), α (allocating,
    /// decreasing queued load) preference — three indexed extremal
    /// queries over the kind's deadline-feasibility prefix instead of a
    /// fleet scan.
    fn efficient_first(
        &self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        for &kind in kinds {
            let bound = req.deadline - view.service_time(kind, req.size);
            if now > bound {
                // Even an instantly-free worker of this kind would miss.
                continue;
            }
            // β: busiest busy worker inside the feasibility prefix.
            if let Some((_, id)) = view.busiest_busy_feasible(kind, bound) {
                return Some(id);
            }
            // ι: idle workers all have busy_until <= now <= bound, so the
            // whole class is feasible — take the most recently idle.
            if let Some((_, id)) = view.most_recently_idle(kind) {
                return Some(id);
            }
            // α: most queued load among feasible spinning-up workers.
            if let Some((_, id)) = view.most_loaded_spinup_feasible(kind, bound) {
                return Some(id);
            }
        }
        None
    }

    /// AutoScale index packing: busiest feasible worker across all kinds;
    /// idle workers rank below any busy worker (packing), most-recently
    /// idle first among idle. Cross-kind ranking compares completion
    /// horizons (`busy_until`) with strict `>` replacement, so equal
    /// horizons keep the earlier kind — the scan's tie order.
    fn index_packing(
        &self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        let mut best_busy: Option<(f64, WorkerId)> = None; // max busy_until
        let mut best_idle: Option<(f64, WorkerId)> = None; // max idle_since
        for &kind in kinds {
            let bound = req.deadline - view.service_time(kind, req.size);
            if now > bound {
                continue;
            }
            if let Some((b, id)) = view.busiest_packed_feasible(kind, bound) {
                if best_busy.map_or(true, |(bb, _)| b > bb) {
                    best_busy = Some((b, id));
                }
            }
            if let Some((s, id)) = view.most_recently_idle(kind) {
                if best_idle.map_or(true, |(bs, _)| s > bs) {
                    best_idle = Some((s, id));
                }
            }
        }
        best_busy.or(best_idle).map(|(_, id)| id)
    }

    /// MArk round robin: resume probing at the next live id after the
    /// last dispatched worker (cycling kinds in `kinds` order, wrapping
    /// back through the starting kind); first feasible worker wins. The
    /// cursor is a (kind, id) position in the live index, so probing
    /// ranges over the index directly — no per-arrival id-list
    /// materialization, and the rotation is stable under workers joining
    /// or leaving between arrivals.
    fn round_robin(
        &mut self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        // Resolve the cursor against this call's kind set; a cursor kind
        // outside `kinds` (caller changed the restriction) resets the
        // rotation to the first kind's smallest id.
        let start = self
            .rr_last
            .and_then(|(k, id)| kinds.iter().position(|&x| x == k).map(|p| (p, id)));
        let (start_pos, last_id) = match start {
            Some((p, id)) => (p, Some(id)),
            None => (0, None),
        };
        let mut found: Option<(WorkerKind, WorkerId)> = None;
        for step in 0..kinds.len() {
            let kind = kinds[(start_pos + step) % kinds.len()];
            let bound = req.deadline - view.service_time(kind, req.size);
            if now > bound {
                continue;
            }
            let after = if step == 0 { last_id } else { None };
            view.for_each_live_id_after(kind, after, &mut |id| {
                let w = view.worker(id).expect("live id vanished mid-probe");
                if w.accepting() && w.busy_until.max(now) <= bound {
                    found = Some((kind, id));
                    return false;
                }
                true
            });
            if found.is_some() {
                break;
            }
        }
        // Wrap-around: the starting kind's ids up to (and including) the
        // cursor — a worker may be re-picked when it is the only feasible
        // one left.
        if found.is_none() {
            if let (Some(last), Some(&kind)) = (last_id, kinds.get(start_pos)) {
                let bound = req.deadline - view.service_time(kind, req.size);
                if now <= bound {
                    view.for_each_live_id_after(kind, None, &mut |id| {
                        if id > last {
                            return false; // past the cursor — already probed
                        }
                        let w = view.worker(id).expect("live id vanished mid-probe");
                        if w.accepting() && w.busy_until.max(now) <= bound {
                            found = Some((kind, id));
                            return false;
                        }
                        true
                    });
                }
            }
        }
        if let Some((kind, id)) = found {
            self.rr_last = Some((kind, id));
            return Some(id);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::policy::WorkerState;
    use crate::sim::SimState;

    /// Build a state with pre-spun workers: (kind, backlog_seconds).
    fn state_with(workers: &[(WorkerKind, f64)]) -> (SimState, Vec<WorkerId>) {
        let mut cfg = SimConfig::paper_default();
        cfg.platform.cpu.spin_up = 0.0;
        cfg.platform.fpga.spin_up = 0.0;
        let mut sim = SimState::new(cfg);
        let ids: Vec<WorkerId> = workers
            .iter()
            .map(|&(kind, backlog)| {
                let id = sim.alloc(kind).unwrap();
                // Force active with the requested backlog (with_mut keeps
                // the pool's ordered indexes coherent).
                sim.pool.with_mut(id, |w| {
                    w.state = WorkerState::Active;
                    w.busy_until = backlog;
                    if backlog > 0.0 {
                        w.queued = 1;
                    }
                });
                id
            })
            .collect();
        (sim, ids)
    }

    fn req(size: f64, deadline: f64) -> Request {
        Request {
            arrival: 0.0,
            size,
            deadline,
            attempt: 0,
        }
    }

    const BOTH: &[WorkerKind] = &WorkerKind::EFFICIENT_FIRST;

    #[test]
    fn efficient_first_prefers_fpga_over_idle_cpu() {
        let (sim, ids) = state_with(&[(WorkerKind::Cpu, 0.0), (WorkerKind::Fpga, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        let got = d.find(&sim, &req(0.010, 0.1), BOTH).unwrap();
        assert_eq!(got, ids[1], "must pick the FPGA");
    }

    #[test]
    fn efficient_first_packs_busiest_feasible() {
        // Two FPGAs, backlogs 0.02 and 0.04; request 10ms (5ms on FPGA)
        // with deadline 0.1: both feasible → busiest (0.04) wins.
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 0.02), (WorkerKind::Fpga, 0.04)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[1]);
        // Tight deadline 0.03: only the 0.02-backlog one fits (0.025<=0.03).
        assert_eq!(d.find(&sim, &req(0.010, 0.030), BOTH).unwrap(), ids[0]);
    }

    #[test]
    fn efficient_first_falls_to_cpu_when_fpgas_infeasible() {
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 10.0), (WorkerKind::Cpu, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[1]);
    }

    #[test]
    fn efficient_first_none_when_nothing_feasible() {
        let (sim, _) = state_with(&[(WorkerKind::Fpga, 10.0), (WorkerKind::Cpu, 10.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert!(d.find(&sim, &req(0.010, 0.1), BOTH).is_none());
    }

    #[test]
    fn index_packing_prefers_busy_cpu_over_idle_fpga() {
        // The hybrid-blindness the paper calls out.
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 0.0), (WorkerKind::Cpu, 0.05)]);
        let mut d = Dispatcher::new(DispatchPolicy::IndexPacking);
        assert_eq!(d.find(&sim, &req(0.010, 1.0), BOTH).unwrap(), ids[1]);
    }

    #[test]
    fn round_robin_rotates() {
        let (sim, ids) = state_with(&[
            (WorkerKind::Fpga, 0.0),
            (WorkerKind::Fpga, 0.0),
            (WorkerKind::Cpu, 0.0),
        ]);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let r = req(0.010, 1.0);
        let picks: Vec<WorkerId> = (0..6)
            .map(|_| d.find(&sim, &r, BOTH).unwrap())
            .collect();
        // cycles through all three workers twice
        assert_eq!(&picks[..3], &picks[3..]);
        let mut uniq = picks[..3].to_vec();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3);
        assert!(ids.iter().all(|id| uniq.contains(id)));
    }

    #[test]
    fn round_robin_skips_infeasible() {
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 10.0), (WorkerKind::Cpu, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        for _ in 0..4 {
            assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[1]);
        }
    }

    #[test]
    fn equal_backlog_ties_resolve_to_lowest_id() {
        // Equal-extremal picks must match the historical id-ascending
        // scan: first (lowest id) of the tied group wins.
        let (sim, ids) = state_with(&[
            (WorkerKind::Fpga, 0.04),
            (WorkerKind::Fpga, 0.04),
            (WorkerKind::Fpga, 0.02),
        ]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[0]);
        let mut d = Dispatcher::new(DispatchPolicy::IndexPacking);
        assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[0]);
    }

    #[test]
    fn round_robin_cursor_survives_churn() {
        // The cursor is a (kind, id) position, so removing a worker
        // between arrivals must not reshuffle the rotation (the old
        // positional cursor pointed into a shifted list).
        let (mut sim, ids) = state_with(&[
            (WorkerKind::Cpu, 0.0),
            (WorkerKind::Cpu, 0.0),
            (WorkerKind::Cpu, 0.0),
        ]);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let r = req(0.010, 1.0);
        assert_eq!(d.find(&sim, &r, BOTH).unwrap(), ids[0]);
        sim.pool.remove(ids[1]);
        // Rotation resumes after ids[0]: next live id is ids[2], then
        // wraps back to ids[0].
        assert_eq!(d.find(&sim, &r, BOTH).unwrap(), ids[2]);
        assert_eq!(d.find(&sim, &r, BOTH).unwrap(), ids[0]);
    }

    #[test]
    fn kind_restriction_respected() {
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 0.0), (WorkerKind::Cpu, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        let got = d.find(&sim, &req(0.010, 1.0), &[WorkerKind::Cpu]).unwrap();
        assert_eq!(got, ids[1]);
    }
}
