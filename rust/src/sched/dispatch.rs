//! Request dispatch policies (paper Table 9 ablation):
//!
//! * **Efficient-first** — Spork's Alg 3 `FindAvailableWorker`: try worker
//!   kinds in efficiency order (FPGA, then CPU); within a kind prefer
//!   (1) busiest workers, (2) most-recently-idle workers, (3) spinning-up
//!   workers with the most queued load — always subject to the deadline
//!   check. Packing onto the busiest workers lets the others drain and be
//!   reclaimed at their idle timeout.
//! * **Index packing** — AutoScale [27] extended naively to hybrid pools:
//!   busiest-first over *all* workers regardless of kind (the paper notes
//!   it "often dispatches to busy but inefficient CPU workers over idle
//!   FPGAs").
//! * **Round robin** — MArk [93]: rotate over allocated workers ("evenly
//!   distributes requests ... rarely lets workers idle").
//!
//! All policies fall back to `None` when no worker can meet the deadline;
//! the caller then spins up a fresh CPU (Alg 3 line 6). The scans run on
//! the transport-agnostic [`PolicyView`], so the same dispatcher serves
//! both the sim driver and the real-time serving driver.

use crate::config::{DispatchPolicy, WorkerKind};
use crate::policy::{PolicyView, Request, WorkerId, WorkerState};

/// Stateful dispatcher (round robin needs a cursor).
#[derive(Clone, Debug)]
pub struct Dispatcher {
    pub policy: DispatchPolicy,
    rr_cursor: usize,
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Self {
        Self { policy, rr_cursor: 0 }
    }

    /// Find a worker for `req` per the policy, restricted to `kinds` (the
    /// homogeneous baselines pass a single kind).
    pub fn find(
        &mut self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        match self.policy {
            DispatchPolicy::EfficientFirst => self.efficient_first(view, req, kinds),
            DispatchPolicy::IndexPacking => self.index_packing(view, req, kinds),
            DispatchPolicy::RoundRobin => self.round_robin(view, req, kinds),
        }
    }

    /// Alg 3: kinds in efficiency order; per kind the β (busy, decreasing
    /// load), ι (idle, increasing idle duration), α (allocating,
    /// decreasing queued load) preference in one O(W) scan.
    fn efficient_first(
        &self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        for &kind in kinds {
            let svc = view.service_time(kind, req.size);
            // Best candidate per preference class.
            let mut best_busy: Option<(f64, WorkerId)> = None; // max backlog
            let mut best_idle: Option<(f64, WorkerId)> = None; // max idle_since (least time idle)
            let mut best_alloc: Option<(f64, WorkerId)> = None; // max queued load
            view.for_each_worker(kind, &mut |w| {
                if !w.accepting() || w.finish_time(now, svc) > req.deadline {
                    return;
                }
                match w.state {
                    WorkerState::Active if w.queued > 0 => {
                        let load = w.busy_until - now;
                        if best_busy.map_or(true, |(l, _)| load > l) {
                            best_busy = Some((load, w.id));
                        }
                    }
                    WorkerState::Active => {
                        if best_idle.map_or(true, |(s, _)| w.idle_since > s) {
                            best_idle = Some((w.idle_since, w.id));
                        }
                    }
                    WorkerState::SpinningUp => {
                        let load = w.busy_until - w.ready_at;
                        if best_alloc.map_or(true, |(l, _)| load > l) {
                            best_alloc = Some((load, w.id));
                        }
                    }
                    WorkerState::SpinningDown => {}
                }
            });
            if let Some((_, id)) = best_busy.or(best_idle).or(best_alloc) {
                return Some(id);
            }
        }
        None
    }

    /// AutoScale index packing: busiest feasible worker across all kinds;
    /// idle workers rank below any busy worker (packing), most-recently
    /// idle first among idle.
    fn index_packing(
        &self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        let mut best_busy: Option<(f64, WorkerId)> = None;
        let mut best_idle: Option<(f64, WorkerId)> = None;
        for &kind in kinds {
            let svc = view.service_time(kind, req.size);
            view.for_each_worker(kind, &mut |w| {
                if !w.accepting() || w.finish_time(now, svc) > req.deadline {
                    return;
                }
                if w.queued > 0 || w.state == WorkerState::SpinningUp {
                    let load = w.busy_until - now;
                    if best_busy.map_or(true, |(l, _)| load > l) {
                        best_busy = Some((load, w.id));
                    }
                } else if best_idle.map_or(true, |(s, _)| w.idle_since > s) {
                    best_idle = Some((w.idle_since, w.id));
                }
            });
        }
        best_busy.or(best_idle).map(|(_, id)| id)
    }

    /// MArk round robin: rotate a cursor across the combined live list;
    /// first feasible worker from the cursor wins.
    fn round_robin(
        &mut self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        let ids: Vec<WorkerId> = kinds
            .iter()
            .flat_map(|&k| view.live_ids(k))
            .collect();
        if ids.is_empty() {
            return None;
        }
        let n = ids.len();
        for probe in 0..n {
            let idx = (self.rr_cursor + probe) % n;
            let w = view.worker(ids[idx]).unwrap();
            let svc = view.service_time(w.kind, req.size);
            if w.accepting() && w.finish_time(now, svc) <= req.deadline {
                self.rr_cursor = (idx + 1) % n;
                return Some(w.id);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::SimState;

    /// Build a state with pre-spun workers: (kind, backlog_seconds).
    fn state_with(workers: &[(WorkerKind, f64)]) -> (SimState, Vec<WorkerId>) {
        let mut cfg = SimConfig::paper_default();
        cfg.platform.cpu.spin_up = 0.0;
        cfg.platform.fpga.spin_up = 0.0;
        let mut sim = SimState::new(cfg);
        let ids: Vec<WorkerId> = workers
            .iter()
            .map(|&(kind, backlog)| {
                let id = sim.alloc(kind).unwrap();
                // Force active with the requested backlog (with_mut keeps
                // the pool's ordered indexes coherent).
                sim.pool.with_mut(id, |w| {
                    w.state = WorkerState::Active;
                    w.busy_until = backlog;
                    if backlog > 0.0 {
                        w.queued = 1;
                    }
                });
                id
            })
            .collect();
        (sim, ids)
    }

    fn req(size: f64, deadline: f64) -> Request {
        Request {
            arrival: 0.0,
            size,
            deadline,
        }
    }

    const BOTH: &[WorkerKind] = &[WorkerKind::Fpga, WorkerKind::Cpu];

    #[test]
    fn efficient_first_prefers_fpga_over_idle_cpu() {
        let (sim, ids) = state_with(&[(WorkerKind::Cpu, 0.0), (WorkerKind::Fpga, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        let got = d.find(&sim, &req(0.010, 0.1), BOTH).unwrap();
        assert_eq!(got, ids[1], "must pick the FPGA");
    }

    #[test]
    fn efficient_first_packs_busiest_feasible() {
        // Two FPGAs, backlogs 0.02 and 0.04; request 10ms (5ms on FPGA)
        // with deadline 0.1: both feasible → busiest (0.04) wins.
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 0.02), (WorkerKind::Fpga, 0.04)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[1]);
        // Tight deadline 0.03: only the 0.02-backlog one fits (0.025<=0.03).
        assert_eq!(d.find(&sim, &req(0.010, 0.030), BOTH).unwrap(), ids[0]);
    }

    #[test]
    fn efficient_first_falls_to_cpu_when_fpgas_infeasible() {
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 10.0), (WorkerKind::Cpu, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[1]);
    }

    #[test]
    fn efficient_first_none_when_nothing_feasible() {
        let (sim, _) = state_with(&[(WorkerKind::Fpga, 10.0), (WorkerKind::Cpu, 10.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        assert!(d.find(&sim, &req(0.010, 0.1), BOTH).is_none());
    }

    #[test]
    fn index_packing_prefers_busy_cpu_over_idle_fpga() {
        // The hybrid-blindness the paper calls out.
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 0.0), (WorkerKind::Cpu, 0.05)]);
        let mut d = Dispatcher::new(DispatchPolicy::IndexPacking);
        assert_eq!(d.find(&sim, &req(0.010, 1.0), BOTH).unwrap(), ids[1]);
    }

    #[test]
    fn round_robin_rotates() {
        let (sim, ids) = state_with(&[
            (WorkerKind::Fpga, 0.0),
            (WorkerKind::Fpga, 0.0),
            (WorkerKind::Cpu, 0.0),
        ]);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let r = req(0.010, 1.0);
        let picks: Vec<WorkerId> = (0..6)
            .map(|_| d.find(&sim, &r, BOTH).unwrap())
            .collect();
        // cycles through all three workers twice
        assert_eq!(&picks[..3], &picks[3..]);
        let mut uniq = picks[..3].to_vec();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3);
        assert!(ids.iter().all(|id| uniq.contains(id)));
    }

    #[test]
    fn round_robin_skips_infeasible() {
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 10.0), (WorkerKind::Cpu, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        for _ in 0..4 {
            assert_eq!(d.find(&sim, &req(0.010, 0.1), BOTH).unwrap(), ids[1]);
        }
    }

    #[test]
    fn kind_restriction_respected() {
        let (sim, ids) = state_with(&[(WorkerKind::Fpga, 0.0), (WorkerKind::Cpu, 0.0)]);
        let mut d = Dispatcher::new(DispatchPolicy::EfficientFirst);
        let got = d.find(&sim, &req(0.010, 1.0), &[WorkerKind::Cpu]).unwrap();
        assert_eq!(got, ids[1]);
    }
}
