//! Perfect-workload-knowledge helpers for the idealized baselines:
//! per-interval needed-FPGA counts computed directly from the trace
//! (FPGA-static's peak provisioning, MArk-ideal's and Spork-*-ideal's
//! predictions, and FPGA-dynamic's headroom sizing), plus the shared
//! [`WorkloadProfile`] that lets every oracle consumer of one workload
//! pay the O(arrivals) binning pass exactly once.

use super::breakeven::{breakeven_fpga_seconds, needed_fpgas, Objective};
use crate::config::SimConfig;
use crate::trace::{AppTrace, ArrivalSource, TraceSource};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct Oracle {
    /// Needed FPGA workers per scheduling interval (breakeven-rounded).
    pub needed: Vec<u32>,
    /// Interval length used.
    pub interval: f64,
    /// Exact arrival count of the workload the oracle was built from —
    /// the denominator of the fitting searches' miss-fraction feasibility
    /// predicate. The oracle pass streams the whole workload anyway, so
    /// counting here is what lets every subsequent search pass arm the
    /// early-abort budget even on generator sources (whose `len_hint` is
    /// unknowable before a full pass).
    pub total_requests: u64,
}

impl Oracle {
    pub fn from_trace(trace: &AppTrace, cfg: &SimConfig, obj: Objective) -> Self {
        Self::from_source(&mut trace.source(), cfg, obj)
    }

    /// Build the per-interval needed-FPGA counts by streaming `src` once:
    /// O(intervals) memory regardless of arrival count. Identical to
    /// [`Oracle::from_trace`] on the materialized equivalent — both use
    /// the shared `trace::interval_bins` / `trace::interval_index`
    /// binning rule and accumulate in arrival order.
    pub fn from_source(src: &mut dyn ArrivalSource, cfg: &SimConfig, obj: Objective) -> Self {
        let interval = cfg.interval;
        let n = crate::trace::interval_bins(src.duration(), interval);
        let mut work = vec![0.0f64; n];
        let mut total_requests = 0u64;
        while let Some(a) = src.next_arrival() {
            work[crate::trace::interval_index(a.time, interval, n)] += a.size;
            total_requests += 1;
        }
        Self::from_bins(&work, total_requests, cfg, obj)
    }

    /// Derive an objective's needed-counts from a cached
    /// [`WorkloadProfile`] — O(intervals), no arrival streaming. Exactly
    /// equal to [`Oracle::from_source`] over the profile's trace: the
    /// profile's bins were accumulated by the same binning rule in the
    /// same arrival order, and the breakeven mapping below is the same
    /// pure function of `(cfg, obj)`.
    pub fn from_profile(profile: &WorkloadProfile, cfg: &SimConfig, obj: Objective) -> Self {
        assert!(
            profile.interval == cfg.interval,
            "profile binned at interval {} but cfg.interval is {}",
            profile.interval,
            cfg.interval
        );
        Self::from_bins(&profile.work_bins, profile.total_requests, cfg, obj)
    }

    /// The shared bins → needed-counts mapping (breakeven rounding under
    /// `cfg`'s platform and `obj`).
    fn from_bins(work: &[f64], total_requests: u64, cfg: &SimConfig, obj: Objective) -> Self {
        let interval = cfg.interval;
        let speedup = cfg.platform.fpga.speedup;
        let tb = breakeven_fpga_seconds(&cfg.platform, interval, obj);
        let needed = work
            .iter()
            .map(|w| needed_fpgas(w / speedup, interval, tb))
            .collect();
        Self {
            needed,
            interval,
            total_requests,
        }
    }

    /// Needed count for the interval containing/indexed `t` (clamped).
    pub fn needed_at(&self, index: usize) -> u32 {
        if self.needed.is_empty() {
            0
        } else {
            self.needed[index.min(self.needed.len() - 1)]
        }
    }

    /// Peak needed count (FPGA-static's provisioning level).
    pub fn peak(&self) -> u32 {
        self.needed.iter().copied().max().unwrap_or(0)
    }

    /// Max difference between consecutive intervals' needed counts —
    /// FPGA-dynamic sizes its headroom as integer multiples of this.
    pub fn max_consecutive_delta(&self) -> u32 {
        self.needed
            .windows(2)
            .map(|w| w[0].abs_diff(w[1]))
            .max()
            .unwrap_or(0)
    }
}

/// One synthesized workload, bound once and shared by every consumer: the
/// materialized trace (`Arc`, cheap to share across sweep units and
/// threads), its per-interval work bins at a fixed scheduling interval,
/// and its exact arrival count.
///
/// A profile is a pure function of the workload identity — for sweep
/// cells, of `(seed_base, seed, workload-spec, interval)` — so caching
/// one per distinct key and fanning it out to every scheduler kind in a
/// grid preserves bit-determinism while paying trace synthesis once
/// instead of once per kind, and the O(arrivals) oracle binning once
/// instead of once per oracle-assisted kind. Platform parameters are
/// deliberately *not* part of a profile: bins are pre-breakeven demand,
/// so sensitivity sweeps that vary speedup or power reuse the same
/// profile and re-derive needed-counts per config via
/// [`Oracle::from_profile`].
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    pub trace: Arc<AppTrace>,
    /// Scheduling interval the bins were accumulated at.
    pub interval: f64,
    /// Per-interval dispatched work in CPU-seconds
    /// (`AppTrace::work_per_interval`).
    pub work_bins: Vec<f64>,
    /// Exact arrival count (`trace.len()`).
    pub total_requests: u64,
}

impl WorkloadProfile {
    pub fn new(trace: Arc<AppTrace>, interval: f64) -> Self {
        let work_bins = trace.work_per_interval(interval);
        Self {
            total_requests: trace.len() as u64,
            interval,
            work_bins,
            trace,
        }
    }

    /// Profile a trace by value.
    pub fn from_trace(trace: AppTrace, interval: f64) -> Self {
        Self::new(Arc::new(trace), interval)
    }

    /// A fresh streaming view of the workload, positioned at t = 0 (what
    /// profile-aware run paths feed the sim driver; its `len_hint` is
    /// exact, so bounded passes arm the early abort for free).
    pub fn source(&self) -> TraceSource<'_> {
        self.trace.source()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{AppTrace, Arrival};

    fn trace_with_interval_work(work: &[f64], interval: f64) -> AppTrace {
        // One big arrival per interval carrying the interval's work.
        let arrivals: Vec<Arrival> = work
            .iter()
            .enumerate()
            .filter(|(_, &w)| w > 0.0)
            .map(|(i, &w)| Arrival {
                time: i as f64 * interval + 0.1,
                size: w,
            })
            .collect();
        AppTrace::new("o", arrivals, interval * work.len() as f64)
    }

    #[test]
    fn needed_counts_follow_work() {
        let cfg = SimConfig::paper_default(); // interval 10, speedup 2
        // 40 CPU-seconds → 20 FPGA-seconds → 2 FPGAs per 10s interval.
        let trace = trace_with_interval_work(&[40.0, 0.0, 80.0], 10.0);
        let o = Oracle::from_trace(&trace, &cfg, Objective::energy());
        assert_eq!(o.needed, vec![2, 0, 4]);
        assert_eq!(o.peak(), 4);
        assert_eq!(o.max_consecutive_delta(), 4);
        assert_eq!(o.total_requests, 2);
    }

    #[test]
    fn breakeven_rounding_applied() {
        let cfg = SimConfig::paper_default();
        // 1 FPGA-second of leftover work (2 CPU-seconds): above the energy
        // threshold (0.74) → 1 FPGA; below the cost threshold (7.35) → 0.
        let trace = trace_with_interval_work(&[2.0], 10.0);
        let e = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let c = Oracle::from_trace(&trace, &cfg, Objective::cost());
        assert_eq!(e.needed, vec![1]);
        assert_eq!(c.needed, vec![0]);
    }

    #[test]
    fn clamping_at_end() {
        let cfg = SimConfig::paper_default();
        let trace = trace_with_interval_work(&[20.0], 10.0);
        let o = Oracle::from_trace(&trace, &cfg, Objective::energy());
        assert_eq!(o.needed_at(0), 1);
        assert_eq!(o.needed_at(99), 1); // clamped
    }

    #[test]
    fn profile_oracle_matches_streaming_oracle() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let trace = crate::trace::synthetic_app("p", &mut rng, 0.65, 120.0, 80.0, 0.010);
        let cfg = SimConfig::paper_default();
        let profile = WorkloadProfile::from_trace(trace.clone(), cfg.interval);
        for obj in [Objective::energy(), Objective::cost()] {
            let streamed = Oracle::from_trace(&trace, &cfg, obj);
            let cached = Oracle::from_profile(&profile, &cfg, obj);
            assert_eq!(streamed.needed, cached.needed);
            assert_eq!(streamed.interval, cached.interval);
            assert_eq!(streamed.total_requests, cached.total_requests);
        }
    }

    #[test]
    #[should_panic(expected = "cfg.interval")]
    fn profile_interval_mismatch_is_loud() {
        let cfg = SimConfig::paper_default();
        let trace = trace_with_interval_work(&[20.0], 10.0);
        let profile = WorkloadProfile::from_trace(trace, cfg.interval * 2.0);
        Oracle::from_profile(&profile, &cfg, Objective::energy());
    }
}
