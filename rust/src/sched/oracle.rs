//! Perfect-workload-knowledge helpers for the idealized baselines:
//! per-interval needed-FPGA counts computed directly from the trace
//! (FPGA-static's peak provisioning, MArk-ideal's and Spork-*-ideal's
//! predictions, and FPGA-dynamic's headroom sizing).

use super::breakeven::{breakeven_fpga_seconds, needed_fpgas, Objective};
use crate::config::SimConfig;
use crate::trace::{AppTrace, ArrivalSource};

#[derive(Clone, Debug)]
pub struct Oracle {
    /// Needed FPGA workers per scheduling interval (breakeven-rounded).
    pub needed: Vec<u32>,
    /// Interval length used.
    pub interval: f64,
}

impl Oracle {
    pub fn from_trace(trace: &AppTrace, cfg: &SimConfig, obj: Objective) -> Self {
        Self::from_source(&mut trace.source(), cfg, obj)
    }

    /// Build the per-interval needed-FPGA counts by streaming `src` once:
    /// O(intervals) memory regardless of arrival count. Identical to
    /// [`Oracle::from_trace`] on the materialized equivalent — both use
    /// the shared `trace::interval_bins` / `trace::interval_index`
    /// binning rule and accumulate in arrival order.
    pub fn from_source(src: &mut dyn ArrivalSource, cfg: &SimConfig, obj: Objective) -> Self {
        let interval = cfg.interval;
        let speedup = cfg.platform.fpga.speedup;
        let tb = breakeven_fpga_seconds(&cfg.platform, interval, obj);
        let n = crate::trace::interval_bins(src.duration(), interval);
        let mut work = vec![0.0f64; n];
        while let Some(a) = src.next_arrival() {
            work[crate::trace::interval_index(a.time, interval, n)] += a.size;
        }
        let needed = work
            .iter()
            .map(|w| needed_fpgas(w / speedup, interval, tb))
            .collect();
        Self { needed, interval }
    }

    /// Needed count for the interval containing/indexed `t` (clamped).
    pub fn needed_at(&self, index: usize) -> u32 {
        if self.needed.is_empty() {
            0
        } else {
            self.needed[index.min(self.needed.len() - 1)]
        }
    }

    /// Peak needed count (FPGA-static's provisioning level).
    pub fn peak(&self) -> u32 {
        self.needed.iter().copied().max().unwrap_or(0)
    }

    /// Max difference between consecutive intervals' needed counts —
    /// FPGA-dynamic sizes its headroom as integer multiples of this.
    pub fn max_consecutive_delta(&self) -> u32 {
        self.needed
            .windows(2)
            .map(|w| w[0].abs_diff(w[1]))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{AppTrace, Arrival};

    fn trace_with_interval_work(work: &[f64], interval: f64) -> AppTrace {
        // One big arrival per interval carrying the interval's work.
        let arrivals: Vec<Arrival> = work
            .iter()
            .enumerate()
            .filter(|(_, &w)| w > 0.0)
            .map(|(i, &w)| Arrival {
                time: i as f64 * interval + 0.1,
                size: w,
            })
            .collect();
        AppTrace::new("o", arrivals, interval * work.len() as f64)
    }

    #[test]
    fn needed_counts_follow_work() {
        let cfg = SimConfig::paper_default(); // interval 10, speedup 2
        // 40 CPU-seconds → 20 FPGA-seconds → 2 FPGAs per 10s interval.
        let trace = trace_with_interval_work(&[40.0, 0.0, 80.0], 10.0);
        let o = Oracle::from_trace(&trace, &cfg, Objective::energy());
        assert_eq!(o.needed, vec![2, 0, 4]);
        assert_eq!(o.peak(), 4);
        assert_eq!(o.max_consecutive_delta(), 4);
    }

    #[test]
    fn breakeven_rounding_applied() {
        let cfg = SimConfig::paper_default();
        // 1 FPGA-second of leftover work (2 CPU-seconds): above the energy
        // threshold (0.74) → 1 FPGA; below the cost threshold (7.35) → 0.
        let trace = trace_with_interval_work(&[2.0], 10.0);
        let e = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let c = Oracle::from_trace(&trace, &cfg, Objective::cost());
        assert_eq!(e.needed, vec![1]);
        assert_eq!(c.needed, vec![0]);
    }

    #[test]
    fn clamping_at_end() {
        let cfg = SimConfig::paper_default();
        let trace = trace_with_interval_work(&[20.0], 10.0);
        let o = Oracle::from_trace(&trace, &cfg, Objective::energy());
        assert_eq!(o.needed_at(0), 1);
        assert_eq!(o.needed_at(99), 1); // clamped
    }
}
