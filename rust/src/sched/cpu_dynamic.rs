//! CPU-dynamic baseline (§5.1): a CPU-only reactive scheduler in the style
//! of serverless frameworks and AutoScale [27] — fast spin-ups absorb
//! bursts, index packing consolidates load, idle timeouts reclaim workers.
//! Equivalent to Spork restricted to CPU workers.

use super::dispatch::Dispatcher;
use crate::config::{DispatchPolicy, WorkerKind};
use crate::policy::{Action, Observation, Policy, PolicyView, Target};

pub struct CpuDynamic {
    dispatcher: Dispatcher,
}

impl CpuDynamic {
    pub fn new() -> Self {
        Self {
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

impl Default for CpuDynamic {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for CpuDynamic {
    fn name(&self) -> String {
        "cpu-dynamic".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY // purely reactive
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &[WorkerKind::Cpu];
        if let Observation::Arrival { req } = obs {
            let to = match self.dispatcher.find(view, &req, KINDS) {
                Some(w) => Target::Worker(w),
                None => Target::Fresh(WorkerKind::Cpu),
            };
            out.push(Action::Dispatch { req, to });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, SimConfig};
    use crate::sim;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn cpu_only_and_roughly_one_sixth_efficiency() {
        let mut rng = Rng::new(1);
        let trace = synthetic_app("c", &mut rng, 0.6, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let r = sim::run(
            &trace,
            cfg,
            &PlatformConfig::paper_default(),
            &mut CpuDynamic::new(),
        );
        assert_eq!(r.metrics.on_fpga, 0);
        assert_eq!(r.metrics.fpga_spinups, 0);
        // Paper Table 8: CPU-dynamic ≈ 16.5% efficiency (1/6 via the busy
        // power ratio, minus overheads).
        let eff = r.energy_efficiency();
        assert!((0.10..0.18).contains(&eff), "efficiency {eff}");
        assert!(r.miss_fraction() < 0.01, "misses {}", r.miss_fraction());
    }

    #[test]
    fn reuses_workers_under_steady_load() {
        let mut rng = Rng::new(2);
        let trace = synthetic_app("c", &mut rng, 0.5, 120.0, 100.0, 0.010);
        let cfg = SimConfig::paper_default();
        let r = sim::run(
            &trace,
            cfg,
            &PlatformConfig::paper_default(),
            &mut CpuDynamic::new(),
        );
        // ~1 CPU-second/s of demand → a handful of CPUs, heavily reused;
        // spin-ups far below one per request.
        assert!(
            (r.metrics.cpu_spinups as f64) < 0.25 * r.metrics.requests as f64,
            "spinups {} vs requests {}",
            r.metrics.cpu_spinups,
            r.metrics.requests
        );
    }
}
