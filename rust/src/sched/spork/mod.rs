//! Spork: the paper's hybrid scheduler (§4).
//!
//! Per-interval FPGA allocation (Alg 1) + histogram predictor (Alg 2, in
//! [`predictor`]) + efficient-first dispatch with reactive CPU spin-up
//! (Alg 3, in [`super::dispatch`]). The objective weights make it SporkE /
//! SporkC / SporkB; `ideal` swaps the predictor for an oracle (perfect
//! next-interval worker counts, no spin-up accounting).

pub mod predictor;

use super::breakeven::{
    breakeven_fpga_seconds, lambda_fpga_seconds, needed_fpgas, Objective,
};
use super::dispatch::Dispatcher;
use super::oracle::Oracle;
use crate::config::{DispatchPolicy, SimConfig, WorkerKind};
use crate::policy::{Action, Observation, Policy, PolicyView, Target};
use predictor::Predictor;

pub struct Spork {
    obj: Objective,
    interval: f64,
    speedup: f64,
    breakeven: f64,
    dispatcher: Dispatcher,
    predictor: Predictor,
    /// Perfect next-interval counts instead of the predictor.
    oracle: Option<Oracle>,
    /// Sliding lag buffer: [n_{t-2}, n_{t-1}] needed counts, so the
    /// histogram can be updated at key n_{t-3} when n_{t-1} materializes.
    lag: Vec<u32>,
    /// §4.5 optional extension: scale allocations down when deadlines are
    /// loose enough that queueing slack absorbs load (off = paper).
    deadline_aware: bool,
    /// Ablation: replace Alg 2 with naive last-value prediction
    /// (n_{t+1} := n_{t-1}).
    last_value_predictor: bool,
}

impl Spork {
    pub fn new(cfg: &SimConfig, obj: Objective) -> Self {
        let interval = cfg.interval;
        Self {
            obj,
            interval,
            speedup: cfg.platform.fpga.speedup,
            breakeven: breakeven_fpga_seconds(&cfg.platform, interval, obj),
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
            predictor: Predictor::new(cfg.platform, interval, obj),
            oracle: None,
            lag: Vec::new(),
            deadline_aware: cfg.deadline_aware,
            last_value_predictor: false,
        }
    }

    /// Ablation variant: naive last-value prediction instead of Alg 2's
    /// conditional histograms (quantifies the predictor's contribution).
    pub fn with_last_value_predictor(mut self) -> Self {
        self.last_value_predictor = true;
        self
    }

    /// Ideal variant: perfect next-interval worker counts (from the trace
    /// oracle), no spin-up overhead accounting (§5.1).
    pub fn ideal(cfg: &SimConfig, obj: Objective, oracle: Oracle) -> Self {
        let mut s = Self::new(cfg, obj);
        s.oracle = Some(oracle);
        s.predictor.set_account_spinup(false);
        s
    }

    /// Table 9 ablation: SporkE's allocation with a different dispatcher.
    pub fn with_dispatch(mut self, policy: DispatchPolicy) -> Self {
        self.dispatcher = Dispatcher::new(policy);
        self
    }

    fn variant_name(&self) -> &'static str {
        if self.obj.w_energy > 0.0 && self.obj.w_cost > 0.0 {
            "spork-b"
        } else if self.obj.w_cost > 0.0 {
            "spork-c"
        } else {
            "spork-e"
        }
    }
}

impl Policy for Spork {
    fn name(&self) -> String {
        if self.oracle.is_some() {
            format!("{}-ideal", self.variant_name())
        } else {
            self.variant_name().to_string()
        }
    }

    fn interval(&self) -> f64 {
        self.interval
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &WorkerKind::EFFICIENT_FIRST;
        match obs {
            Observation::Start => {
                // Cold start (§5.1: no warm-up). The ideal variants may
                // pre-spin for the first interval since they know the
                // workload.
                if let Some(oracle) = &self.oracle {
                    let n0 = oracle.needed_at(0).max(oracle.needed_at(1));
                    out.push(Action::Alloc {
                        kind: WorkerKind::Fpga,
                        n: n0,
                        prewarmed: true,
                    });
                }
            }
            Observation::Tick {
                index,
                cpu_work,
                fpga_work,
            } => {
                // Interval t just ended; we stand at the start of interval
                // t+1 and decide allocations that become ready for interval
                // t+2... i.e. the paper's "predict n_{t+1} rather than n_t"
                // at lag one. Alg 1 lines 6-8: needed FPGAs in the interval
                // that just ended.
                let lambda = lambda_fpga_seconds(cpu_work, fpga_work, self.speedup);
                let n_needed = needed_fpgas(lambda, self.interval, self.breakeven);

                // ℍ[n_{t-3}].add(n_{t-1})
                self.lag.push(n_needed);
                if self.lag.len() > 2 {
                    let key = self.lag.remove(0);
                    self.predictor.observe(key, n_needed);
                }

                let n_curr = view.allocated(WorkerKind::Fpga);
                let n_next = match &self.oracle {
                    Some(oracle) => oracle.needed_at(index + 1),
                    None if self.last_value_predictor => n_needed,
                    None => self.predictor.predict(n_needed, n_curr),
                };
                let n_next = if self.deadline_aware {
                    // Optional §4.5 extension: with loose deadlines
                    // (relative to the interval) a small under-allocation
                    // is absorbed by queueing slack; shave one worker when
                    // slack is ample.
                    n_next.saturating_sub(1).max(n_needed.min(n_next))
                } else {
                    n_next
                };

                if n_next > n_curr {
                    out.push(Action::Alloc {
                        kind: WorkerKind::Fpga,
                        n: n_next - n_curr,
                        prewarmed: false,
                    });
                }
                // Over-allocations are reclaimed by the idle timeout (§5.1),
                // not forced down — the "insurance against repetitive
                // allocations".
            }
            Observation::Arrival { req } => {
                let to = match self.dispatcher.find(view, &req, KINDS) {
                    Some(w) => Target::Worker(w),
                    // Alg 3 line 6: burst / under-allocation → fresh CPU.
                    None => Target::Fresh(WorkerKind::Cpu),
                };
                out.push(Action::Dispatch { req, to });
            }
            Observation::Dealloc {
                kind,
                lifetime,
                peers_at_alloc,
            } => {
                if kind == WorkerKind::Fpga {
                    self.predictor.observe_lifetime(peers_at_alloc, lifetime);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::sim;
    use crate::trace::{synthetic_app, AppTrace, Arrival};
    use crate::util::rng::Rng;

    fn steady_trace(rate_per_sec: f64, size: f64, duration: f64) -> AppTrace {
        let mut arrivals = Vec::new();
        let n_per_sec = rate_per_sec as usize;
        let mut t = 0.0;
        while t < duration {
            for k in 0..n_per_sec {
                arrivals.push(Arrival {
                    time: t + k as f64 / rate_per_sec,
                    size,
                });
            }
            t += 1.0;
        }
        AppTrace::new("steady", arrivals, duration)
    }

    #[test]
    fn steady_load_converges_to_fpgas() {
        // 200 req/s x 10ms = 2 CPU-s/s = 1 FPGA-s/s → 1 FPGA covers it.
        let trace = steady_trace(200.0, 0.010, 120.0);
        let cfg = SimConfig::paper_default();
        let mut sched = Spork::new(&cfg, Objective::energy());
        let r = sim::run(&trace, cfg, &PlatformConfig::paper_default(), &mut sched);
        let m = &r.metrics;
        // After warm-up, most requests run on FPGAs.
        assert!(
            m.cpu_request_fraction() < 0.25,
            "cpu fraction {}",
            m.cpu_request_fraction()
        );
        assert!(m.on_fpga > 0);
        // FPGA allocation should be modest (predictor converges to ~1-2).
        assert!(m.peak_fpgas <= 4, "peak fpgas {}", m.peak_fpgas);
        assert_eq!(m.requests as usize, trace.len());
    }

    #[test]
    fn deadlines_mostly_met_via_cpu_fallback() {
        let mut rng = Rng::new(42);
        let trace = synthetic_app("b", &mut rng, 0.65, 300.0, 150.0, 0.010);
        let cfg = SimConfig::paper_default();
        let mut sched = Spork::new(&cfg, Objective::energy());
        let r = sim::run(&trace, cfg, &PlatformConfig::paper_default(), &mut sched);
        assert!(
            r.miss_fraction() < 0.01,
            "miss fraction {}",
            r.miss_fraction()
        );
    }

    #[test]
    fn spork_e_more_efficient_spork_c_cheaper() {
        let mut rng = Rng::new(7);
        let trace = synthetic_app("b", &mut rng, 0.65, 600.0, 300.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let re = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut Spork::new(&cfg, Objective::energy()),
        );
        let rc = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut Spork::new(&cfg, Objective::cost()),
        );
        assert!(
            re.energy_efficiency() >= rc.energy_efficiency() * 0.98,
            "E {} vs C {}",
            re.energy_efficiency(),
            rc.energy_efficiency()
        );
        assert!(
            rc.relative_cost() <= re.relative_cost() * 1.02,
            "E {} vs C {}",
            re.relative_cost(),
            rc.relative_cost()
        );
    }

    #[test]
    fn ideal_at_least_as_good_on_objective() {
        let mut rng = Rng::new(11);
        let trace = synthetic_app("b", &mut rng, 0.7, 600.0, 300.0, 0.010);
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let r = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut Spork::new(&cfg, Objective::energy()),
        );
        let oracle = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let ri = sim::run(
            &trace,
            cfg.clone(),
            &defaults,
            &mut Spork::ideal(&cfg, Objective::energy(), oracle),
        );
        assert!(
            ri.energy_efficiency() >= r.energy_efficiency() * 0.95,
            "ideal {} vs learned {}",
            ri.energy_efficiency(),
            r.energy_efficiency()
        );
    }

    #[test]
    fn names() {
        let cfg = SimConfig::paper_default();
        assert_eq!(Spork::new(&cfg, Objective::energy()).name(), "spork-e");
        assert_eq!(Spork::new(&cfg, Objective::cost()).name(), "spork-c");
        assert_eq!(Spork::new(&cfg, Objective::balanced()).name(), "spork-b");
        let o = Oracle {
            needed: vec![0],
            interval: 10.0,
            total_requests: 0,
        };
        assert_eq!(
            Spork::ideal(&cfg, Objective::energy(), o).name(),
            "spork-e-ideal"
        );
    }
}
