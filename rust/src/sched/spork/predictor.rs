//! Spork's lightweight worker-count predictor (paper Alg 2).
//!
//! State:
//! * ℍ — a map of histograms: `ℍ[k]` is the empirical distribution of the
//!   FPGA worker count needed in an interval, conditioned on `k` workers
//!   having been needed **two intervals earlier** (allocation takes one
//!   interval, so the decision is made at lag 2).
//! * 𝕃 — the average FPGA worker lifetime conditioned on the number of
//!   workers already allocated when it was requested, used to amortize
//!   spin-up energy over the worker's expected life.
//!
//! Prediction: over candidate counts n̂ spanning the conditional
//! histogram's support (including values between observed bins), pick the
//! n̂ minimizing the expected objective — the probability-weighted sum of
//! over-allocation (busy + idle FPGA) and under-allocation (busy FPGA +
//! burst CPUs) terms plus amortized spin-up for workers beyond the
//! currently allocated count. The objective generalizes the paper's
//! energy-only description to the weighted energy/cost score of §4.4.
//!
//! Results are cached per (conditioning count, current count) and lazily
//! invalidated when the relevant histogram or 𝕃 changes.

use super::super::breakeven::Objective;
use crate::config::PlatformConfig;
use crate::util::stats::{CountHistogram, MeanTracker};
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Predictor {
    hist: HashMap<u32, CountHistogram>,
    hist_version: HashMap<u32, u64>,
    lifetimes: HashMap<u32, MeanTracker>,
    life_version: u64,
    cache: HashMap<(u32, u32), CacheEntry>,
    obj: Objective,
    platform: PlatformConfig,
    interval: f64,
    /// Whether to amortize spin-up overheads (the ideal variants skip
    /// this — §5.1 "ignoring spin-up overhead accounting").
    account_spinup: bool,
}

#[derive(Clone, Copy, Debug)]
struct CacheEntry {
    hist_version: u64,
    life_version: u64,
    result: u32,
}

impl Predictor {
    pub fn new(platform: PlatformConfig, interval: f64, obj: Objective) -> Self {
        Self {
            hist: HashMap::new(),
            hist_version: HashMap::new(),
            lifetimes: HashMap::new(),
            life_version: 0,
            cache: HashMap::new(),
            obj,
            platform,
            interval,
            account_spinup: true,
        }
    }

    /// Record that `needed` workers were needed in an interval whose lag-2
    /// predecessor needed `key` workers (Alg 1 line 8: ℍ[n_{t-3}].add(n_{t-1})).
    pub fn observe(&mut self, key: u32, needed: u32) {
        self.hist.entry(key).or_default().add(needed);
        *self.hist_version.entry(key).or_insert(0) += 1;
    }

    /// Record a deallocated worker's lifetime, conditioned on the peers
    /// allocated when it spun up (𝕃 update).
    pub fn observe_lifetime(&mut self, peers_at_alloc: u32, lifetime: f64) {
        self.lifetimes.entry(peers_at_alloc).or_default().add(lifetime);
        self.life_version += 1;
    }

    /// Alg 2: predict the count for the next interval given the count
    /// needed in the previous interval (`n_prev`) and the currently
    /// allocated count (`n_curr`).
    pub fn predict(&mut self, n_prev: u32, n_curr: u32) -> u32 {
        let hv = self.hist_version.get(&n_prev).copied().unwrap_or(0);
        if let Some(c) = self.cache.get(&(n_prev, n_curr)) {
            if c.hist_version == hv && c.life_version == self.life_version {
                return c.result;
            }
        }
        let result = self.predict_uncached(n_prev, n_curr);
        self.cache.insert(
            (n_prev, n_curr),
            CacheEntry {
                hist_version: hv,
                life_version: self.life_version,
                result,
            },
        );
        result
    }

    fn predict_uncached(&self, n_prev: u32, n_curr: u32) -> u32 {
        let hist = match self.hist.get(&n_prev) {
            // First sighting of this count: keep the previous need (Alg 2
            // lines 4-6).
            None => return n_prev,
            Some(h) if h.is_empty() => return n_prev,
            Some(h) => h,
        };
        let lo = hist.min_bin().unwrap();
        let hi = hist.max_bin().unwrap();
        let probs: Vec<(u32, f64)> = hist.probs().collect();
        let mut best = (f64::INFINITY, n_prev);
        for cand in lo..=hi {
            let score = self.expected_score(cand, n_curr, &probs);
            if score < best.0 {
                best = (score, cand);
            }
        }
        best.1
    }

    /// Expected objective score of allocating `cand` workers for the next
    /// interval, over the conditional distribution `probs`.
    fn expected_score(&self, cand: u32, n_curr: u32, probs: &[(u32, f64)]) -> f64 {
        let p = &self.platform;
        let ts = self.interval;
        let s = p.fpga.speedup;
        let mut energy = 0.0;
        let mut cost = 0.0;

        // Amortized spin-up overhead for workers beyond the current
        // allocation (Alg 2 lines 11-15).
        if self.account_spinup && cand > n_curr {
            for n_new in 0..(cand - n_curr) {
                let avg_life = self
                    .lifetimes
                    .get(&(n_curr + n_new))
                    .map(|m| m.mean())
                    // No lifetime data yet: assume the minimum life — one
                    // spin-up plus one idle-timeout interval.
                    .unwrap_or(p.fpga.spin_up + ts);
                let epochs = (avg_life / ts).ceil().max(1.0);
                energy += p.fpga.busy_power * p.fpga.spin_up / epochs;
                cost += p.fpga.cost_per_sec() * p.fpga.spin_up / epochs;
            }
        }

        for &(n, prob) in probs {
            let (idle_e, busy_e, extra_cost) = if cand >= n {
                // Over-allocation: n busy FPGAs, cand-n idle FPGAs.
                (
                    (cand - n) as f64 * p.fpga.idle_power * ts,
                    n as f64 * p.fpga.busy_power * ts,
                    cand as f64 * p.fpga.cost_per_sec() * ts,
                )
            } else {
                // Under-allocation: cand busy FPGAs; the missing (n-cand)
                // FPGA-intervals of work run on burst CPUs (S x slower).
                let cpu_secs = (n - cand) as f64 * s * ts;
                (
                    0.0,
                    cand as f64 * p.fpga.busy_power * ts + cpu_secs * p.cpu.busy_power,
                    cand as f64 * p.fpga.cost_per_sec() * ts
                        + cpu_secs * p.cpu.cost_per_sec(),
                )
            };
            energy += prob * (idle_e + busy_e);
            cost += prob * extra_cost;
        }
        self.obj.score(energy, cost, p, ts)
    }

    /// Test/introspection access.
    pub fn histogram(&self, key: u32) -> Option<&CountHistogram> {
        self.hist.get(&key)
    }

    pub fn set_account_spinup(&mut self, on: bool) {
        self.account_spinup = on;
        self.cache.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn predictor(obj: Objective) -> Predictor {
        Predictor::new(PlatformConfig::paper_default(), 10.0, obj)
    }

    #[test]
    fn unseen_count_keeps_previous() {
        let mut p = predictor(Objective::energy());
        assert_eq!(p.predict(7, 0), 7);
    }

    #[test]
    fn deterministic_history_predicts_exactly() {
        let mut p = predictor(Objective::energy());
        for _ in 0..20 {
            p.observe(5, 8);
        }
        assert_eq!(p.predict(5, 8), 8);
    }

    #[test]
    fn energy_objective_leans_high_cost_leans_low() {
        // Distribution: 50/50 between needing 2 and needing 10 workers.
        // Under-allocation burns 6x energy on CPUs → energy-optimal leans
        // high; over-allocation burns FPGA occupancy dollars → the
        // cost-optimal pick is lower.
        let mut pe = predictor(Objective::energy());
        let mut pc = predictor(Objective::cost());
        for _ in 0..50 {
            pe.observe(4, 2);
            pe.observe(4, 10);
            pc.observe(4, 2);
            pc.observe(4, 10);
        }
        let e = pe.predict(4, 10);
        let c = pc.predict(4, 10);
        assert!(e > c, "energy pick {e} should exceed cost pick {c}");
        assert_eq!(e, 10, "6x energy gap makes full coverage optimal");
    }

    #[test]
    fn balanced_between_extremes() {
        let mut pe = predictor(Objective::energy());
        let mut pb = predictor(Objective::balanced());
        let mut pc = predictor(Objective::cost());
        for p in [&mut pe, &mut pb, &mut pc] {
            for _ in 0..50 {
                p.observe(4, 1);
                p.observe(4, 12);
            }
        }
        let (e, b, c) = (pe.predict(4, 12), pb.predict(4, 12), pc.predict(4, 12));
        assert!(e >= b && b >= c, "{e} {b} {c}");
    }

    #[test]
    fn spinup_amortization_discourages_growth() {
        // Short observed lifetimes make spinning up extra workers pricey.
        let mut with = predictor(Objective::energy());
        let mut without = predictor(Objective::energy());
        without.set_account_spinup(false);
        for p in [&mut with, &mut without] {
            // Needing 3, sometimes 4 — borderline case.
            for _ in 0..10 {
                p.observe(3, 3);
            }
            for _ in 0..3 {
                p.observe(3, 4);
            }
        }
        // Very short lifetimes: one interval each.
        for k in 0..10 {
            with.observe_lifetime(k, 10.0);
        }
        let a = with.predict(3, 0);
        let b = without.predict(3, 0);
        assert!(a <= b, "amortized spin-up must not pick more workers ({a} vs {b})");
    }

    #[test]
    fn cache_invalidation_on_observe() {
        let mut p = predictor(Objective::energy());
        for _ in 0..5 {
            p.observe(2, 3);
        }
        assert_eq!(p.predict(2, 3), 3);
        // Shift the distribution drastically; prediction must follow.
        for _ in 0..100 {
            p.observe(2, 9);
        }
        assert_eq!(p.predict(2, 9), 9);
    }

    #[test]
    fn candidates_cover_between_bins() {
        // Bins at 0 and 10 with heavy mass at both: intermediate candidate
        // can win under a balanced objective; at minimum the predictor
        // must consider it without panicking.
        let mut p = predictor(Objective::balanced());
        for _ in 0..10 {
            p.observe(1, 0);
            p.observe(1, 10);
        }
        let n = p.predict(1, 0);
        assert!(n <= 10);
    }
}
