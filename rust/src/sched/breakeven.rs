//! Breakeven service thresholds (paper Eq. 1 and §4.4) and the
//! NeededFPGAs rounding rule (Alg 1 lines 13-17).
//!
//! Given leftover per-interval work `x` (measured in **FPGA-service
//! seconds**, i.e. already divided by the speedup S), running it on one
//! additional FPGA for the interval beats CPUs when `x` exceeds a
//! threshold:
//!
//! * **Energy** (Eq. 1, rearranged to FPGA-second units): an extra FPGA
//!   costs `x·B_f + (T_s - x)·I_f` joules vs `x·S·B_c` on CPUs (CPU idle
//!   energy is negligible — CPUs live only as long as the burst), so
//!   `T_b = T_s·I_f / (S·B_c - B_f + I_f)`.
//! * **Cost** (§4.4): an extra FPGA occupies the whole interval
//!   (`T_s·C_f`) vs CPU occupancy for just the work (`x·S·C_c`), so
//!   `T_b = T_s·C_f / (S·C_c)`.
//! * **Weighted objectives** interpolate linearly after normalizing both
//!   objectives to "busy-FPGA-interval equivalents" (energy by `B_f·T_s`,
//!   cost by `C_f·T_s`), which is how SporkB blends the two metrics.

use crate::config::PlatformConfig;

/// Objective weights (w_energy, w_cost). SporkE = (1,0), SporkC = (0,1),
/// SporkB = (0.5,0.5). Weights need not sum to 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objective {
    pub w_energy: f64,
    pub w_cost: f64,
}

impl Objective {
    pub fn energy() -> Self {
        Self { w_energy: 1.0, w_cost: 0.0 }
    }
    pub fn cost() -> Self {
        Self { w_energy: 0.0, w_cost: 1.0 }
    }
    pub fn balanced() -> Self {
        Self { w_energy: 0.5, w_cost: 0.5 }
    }

    /// Normalized score of an (energy J, cost $) pair, in units of
    /// "busy-FPGA-intervals".
    pub fn score(&self, energy: f64, cost: f64, p: &PlatformConfig, interval: f64) -> f64 {
        let e_unit = p.fpga.busy_power * interval;
        let c_unit = p.fpga.cost_per_sec() * interval;
        self.w_energy * energy / e_unit + self.w_cost * cost / c_unit
    }
}

/// Breakeven threshold `T_b` in FPGA-service seconds: leftover interval
/// work above this is worth an additional FPGA under the objective.
pub fn breakeven_fpga_seconds(p: &PlatformConfig, interval: f64, obj: Objective) -> f64 {
    let s = p.fpga.speedup;
    // Score of running x FPGA-seconds of leftover work:
    //   on an extra FPGA: energy x·B_f + (T-x)·I_f, cost T·c_f
    //   on burst CPUs:    energy x·S·B_c,           cost x·S·c_c
    // Both scores are affine in x; solve score_fpga(x) = score_cpu(x).
    let e_unit = p.fpga.busy_power * interval;
    let c_unit = p.fpga.cost_per_sec() * interval;
    // score_fpga(x) = a1 + b1 x ; score_cpu(x) = b2 x
    let a1 = obj.w_energy * (p.fpga.idle_power * interval) / e_unit + obj.w_cost; // wC·(T·c_f)/(T·c_f)=wC
    let b1 = obj.w_energy * (p.fpga.busy_power - p.fpga.idle_power) / e_unit;
    let b2 = obj.w_energy * (s * p.cpu.busy_power) / e_unit
        + obj.w_cost * (s * p.cpu.cost_per_sec()) / c_unit;
    if b2 <= b1 {
        // CPUs never catch up: an FPGA is never worth it for leftovers.
        return f64::INFINITY;
    }
    (a1 / (b2 - b1)).min(interval)
}

/// Alg 1's NeededFPGAs: workers needed to serve `lambda` FPGA-service
/// seconds in an interval, rounding the remainder via the breakeven
/// threshold.
pub fn needed_fpgas(lambda: f64, interval: f64, threshold: f64) -> u32 {
    if lambda <= 0.0 {
        return 0;
    }
    let n = (lambda / interval).floor();
    let rem = lambda - n * interval;
    let mut n = n as u32;
    if rem > threshold {
        n += 1;
    }
    n
}

/// Aggregate demand λ from per-kind served service-time sums (Alg 1 line
/// 13): FPGA seconds count as-is, CPU seconds are divided by S.
pub fn lambda_fpga_seconds(cpu_service: f64, fpga_service: f64, speedup: f64) -> f64 {
    fpga_service + cpu_service / speedup
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> PlatformConfig {
        PlatformConfig::paper_default()
    }

    #[test]
    fn energy_threshold_matches_eq1() {
        // T_b(FPGA-s) = T·I_f / (S·B_c - B_f + I_f) = 10·20/(300-50+20)
        let t = breakeven_fpga_seconds(&p(), 10.0, Objective::energy());
        assert!((t - 200.0 / 270.0).abs() < 1e-9, "t={t}");
        // Back in CPU-seconds (×S) this is Eq.1's closed form:
        // T_b·B_c = (T_b/S)·B_f + (T - T_b/S)·I_f
        let tb_cpu = t * 2.0;
        let lhs = tb_cpu * 150.0;
        let rhs = tb_cpu / 2.0 * 50.0 + (10.0 - tb_cpu / 2.0) * 20.0;
        assert!((lhs - rhs).abs() < 1e-6);
    }

    #[test]
    fn cost_threshold_matches_section_4_4() {
        // T_b = T·C_f/(S·C_c) = 10·0.982/(2·0.668)
        let t = breakeven_fpga_seconds(&p(), 10.0, Objective::cost());
        assert!((t - 10.0 * 0.982 / (2.0 * 0.668)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn balanced_threshold_between_extremes() {
        let te = breakeven_fpga_seconds(&p(), 10.0, Objective::energy());
        let tc = breakeven_fpga_seconds(&p(), 10.0, Objective::cost());
        let tb = breakeven_fpga_seconds(&p(), 10.0, Objective::balanced());
        assert!(te < tb && tb < tc, "{te} {tb} {tc}");
    }

    #[test]
    fn threshold_capped_at_interval() {
        // Make CPUs almost free: threshold would exceed the interval.
        let mut plat = p();
        plat.cpu.busy_power = 1.0;
        plat.cpu.idle_power = 0.5;
        plat.cpu.cost_per_hour = 0.001;
        let t = breakeven_fpga_seconds(&plat, 10.0, Objective::cost());
        assert!(t <= 10.0);
    }

    #[test]
    fn needed_fpgas_rounding() {
        let tb = 0.74;
        assert_eq!(needed_fpgas(0.0, 10.0, tb), 0);
        assert_eq!(needed_fpgas(0.5, 10.0, tb), 0); // below threshold
        assert_eq!(needed_fpgas(1.0, 10.0, tb), 1); // above threshold
        assert_eq!(needed_fpgas(10.0, 10.0, tb), 1); // exact fit
        assert_eq!(needed_fpgas(20.6, 10.0, tb), 2); // remainder below
        assert_eq!(needed_fpgas(21.0, 10.0, tb), 3); // remainder above
    }

    #[test]
    fn lambda_weights_cpu_work_by_speedup() {
        assert!((lambda_fpga_seconds(4.0, 3.0, 2.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn score_normalization() {
        let plat = p();
        // One busy FPGA-interval of energy = score 1 under pure energy.
        let s = Objective::energy().score(50.0 * 10.0, 0.0, &plat, 10.0);
        assert!((s - 1.0).abs() < 1e-12);
        let s = Objective::cost().score(0.0, 0.982 / 3600.0 * 10.0, &plat, 10.0);
        assert!((s - 1.0).abs() < 1e-12);
    }
}
