//! FPGA-static baseline (§5.1): the best-case statically provisioned
//! FPGA-only platform — perfect workload knowledge, pre-allocates enough
//! FPGAs for the peak per-interval load, one-time spin-up cost, the fleet
//! pinned for the whole trace (static platforms do not autoscale [65,73]).
//!
//! The peak per-interval demand gives ρ ≈ 1 during the peak interval,
//! which transiently violates tight (10x-service) deadlines; the paper's
//! best case "meets request deadlines", so [`fit`] searches for the least
//! fleet ≥ peak that does.

use super::dispatch::Dispatcher;
use super::oracle::Oracle;
use crate::config::{DispatchPolicy, PlatformConfig, SimConfig, WorkerKind};
use crate::sim::{self, Request, RunResult, Scheduler, SimState, WorkerId};
use crate::trace::AppTrace;

pub struct FpgaStatic {
    fleet: u32,
    dispatcher: Dispatcher,
}

impl FpgaStatic {
    pub fn new(oracle: &Oracle) -> Self {
        Self::with_fleet(oracle.peak().max(1))
    }

    /// Explicit fleet size (used by [`fit`]).
    pub fn with_fleet(fleet: u32) -> Self {
        Self {
            fleet: fleet.max(1),
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

/// Best-case static provisioning: least fleet ≥ oracle peak whose run
/// meets deadlines (`miss_tolerance` fraction). Step size scales with
/// √peak (square-root staffing). Returns the run and the fleet size.
pub fn fit(
    trace: &AppTrace,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    let oracle = Oracle::from_trace(trace, cfg, super::breakeven::Objective::energy());
    let peak = oracle.peak().max(1);
    let step = ((peak as f64).sqrt().ceil() as u32).max(1);
    let mut best: Option<(RunResult, u32)> = None;
    for j in 0..=8u32 {
        let fleet = peak + j * step;
        let mut sched = FpgaStatic::with_fleet(fleet);
        let r = sim::run(trace, cfg.clone(), defaults, &mut sched);
        let miss = r.miss_fraction();
        best = Some((r, fleet));
        if miss <= miss_tolerance {
            break;
        }
    }
    best.unwrap()
}

impl Scheduler for FpgaStatic {
    fn name(&self) -> String {
        "fpga-static".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY // static: no periodic decisions
    }

    fn on_start(&mut self, sim: &mut SimState) {
        // Statically provisioned before the workload window (the paper's
        // static platform pays a "minor one-time spin-up cost" but is
        // ready when traffic starts).
        sim.alloc_prewarmed(WorkerKind::Fpga, self.fleet);
    }

    fn keep_alive(&self, _worker: WorkerId, sim: &SimState) -> bool {
        // Statically provisioned: the fleet is pinned until the trace
        // ends, then drains through the normal idle timeout.
        sim.trace_live()
    }

    fn on_request(&mut self, req: Request, sim: &mut SimState) {
        const KINDS: &[WorkerKind] = &[WorkerKind::Fpga];
        match self.dispatcher.find(sim, &req, KINDS) {
            Some(w) => {
                sim.dispatch(req, w);
            }
            None => {
                // FPGA-only: no CPU escape hatch. Best-effort onto the
                // earliest-finishing FPGA (a deadline miss if truly full).
                let best: Option<WorkerId> = sim
                    .pool
                    .iter_kind(WorkerKind::Fpga)
                    .filter(|w| w.accepting())
                    .min_by(|a, b| a.busy_until.partial_cmp(&b.busy_until).unwrap())
                    .map(|w| w.id);
                match best {
                    Some(w) => {
                        sim.dispatch(req, w);
                    }
                    None => {
                        // Entire fleet reclaimed by idle timeout (deep lull
                        // longer than the timeout): re-provision.
                        let w = sim
                            .alloc(WorkerKind::Fpga)
                            .expect("FPGA cap must allow static provisioning");
                        sim.dispatch(req, w);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, SimConfig};
    use crate::sched::breakeven::Objective;
    use crate::sim;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn provisions_peak_and_serves_fpga_only() {
        let mut rng = Rng::new(3);
        let trace = synthetic_app("f", &mut rng, 0.6, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let oracle = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let (r, fleet) = fit(&trace, &cfg, &PlatformConfig::paper_default(), 0.005);
        assert_eq!(r.metrics.on_cpu, 0);
        assert!(fleet >= oracle.peak());
        assert!(r.metrics.peak_fpgas >= oracle.peak());
        assert!(r.miss_fraction() < 0.05, "misses {}", r.miss_fraction());
    }

    #[test]
    fn uniform_load_is_energy_efficient_but_costly() {
        let mut rng = Rng::new(4);
        let trace = synthetic_app("f", &mut rng, 0.5, 600.0, 400.0, 0.010);
        let cfg = SimConfig::paper_default();
        let oracle = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let r = sim::run(
            &trace,
            cfg,
            &PlatformConfig::paper_default(),
            &mut FpgaStatic::new(&oracle),
        );
        // At b=0.5 (uniform), static FPGA is near-ideal on energy.
        assert!(
            r.energy_efficiency() > 0.5,
            "efficiency {}",
            r.energy_efficiency()
        );
        // But pays for the full fleet the whole time.
        assert!(r.relative_cost() > 1.0);
    }
}
