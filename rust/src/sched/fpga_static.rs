//! FPGA-static baseline (§5.1): the best-case statically provisioned
//! FPGA-only platform — perfect workload knowledge, pre-allocates enough
//! FPGAs for the peak per-interval load, one-time spin-up cost, the fleet
//! pinned for the whole trace (static platforms do not autoscale [65,73]).
//!
//! The peak per-interval demand gives ρ ≈ 1 during the peak interval,
//! which transiently violates tight (10x-service) deadlines; the paper's
//! best case "meets request deadlines", so [`fitted`] searches for the
//! least fleet ≥ peak that does, and the `sched::build` factory always
//! hands out the fitted policy.

use super::dispatch::Dispatcher;
use super::fit::{self, FitEngine, FitStats};
use super::oracle::{Oracle, WorkloadProfile};
use super::MakeSource;
use crate::config::{DispatchPolicy, PlatformConfig, SimConfig, WorkerKind};
use crate::policy::{
    earliest_finishing, Action, Observation, Policy, PolicyView, Target,
};
use crate::sim::{IdealBaseline, RunResult};
use crate::trace::AppTrace;

pub struct FpgaStatic {
    fleet: u32,
    dispatcher: Dispatcher,
}

impl FpgaStatic {
    pub fn new(oracle: &Oracle) -> Self {
        Self::with_fleet(oracle.peak().max(1))
    }

    /// Explicit fleet size (used by [`fitted`]).
    pub fn with_fleet(fleet: u32) -> Self {
        Self {
            fleet: fleet.max(1),
            dispatcher: Dispatcher::new(DispatchPolicy::EfficientFirst),
        }
    }
}

/// The fitting search: least fleet ≥ the oracle peak whose run meets
/// deadlines within `miss_tolerance`. Step size scales with √peak
/// (square-root staffing). Returns the winning run (normalized against
/// `cfg.platform`), the fleet, and the pass accounting.
///
/// Feasibility is monotone in the fleet, so the search needs O(log j)
/// feasibility probes, and every infeasible probe early-aborts at its
/// miss budget (the oracle pass counted the workload's exact arrivals,
/// so the budget is exact even on generator streams). Every pass streams
/// a fresh source from `make`, so the search runs in constant memory for
/// any trace length. The `engine` picks how probes map onto stream
/// traversals: [`FitEngine::Lockstep`] batches the gallop ladder and the
/// bisect bracket through shared traversals (≤ 2 full-trace equivalents
/// for ordinary fits — the default for streaming entry points, where a
/// traversal re-synthesizes the stream); [`FitEngine::Serial`] probes one
/// candidate per traversal (the materialized-profile path, where
/// re-traversal is free and gallop+bisect simulates the fewest
/// candidates).
fn search(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    miss_tolerance: f64,
    engine: FitEngine,
) -> (RunResult, u32, FitStats) {
    let oracle =
        Oracle::from_source(&mut *make(), cfg, super::breakeven::Objective::energy());
    search_with_oracle(&oracle, make, cfg, miss_tolerance, engine)
}

/// [`search`] with a precomputed oracle (the profile-cached sweep path).
fn search_with_oracle(
    oracle: &Oracle,
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    miss_tolerance: f64,
    engine: FitEngine,
) -> (RunResult, u32, FitStats) {
    let peak = oracle.peak().max(1);
    let step = ((peak as f64).sqrt().ceil() as u32).max(1);
    let total = oracle.total_requests;
    let fleet_of = |j: u32| peak.saturating_add(j.saturating_mul(step));
    let (r, j, stats) = match engine {
        FitEngine::Serial => {
            fit::fit_least_feasible("fpga-static", total, miss_tolerance, &mut |j, bounded| {
                let mut policy = FpgaStatic::with_fleet(fleet_of(j));
                fit::run_candidate_pass(make, total, cfg, miss_tolerance, bounded, &mut policy)
            })
        }
        FitEngine::Lockstep => fit::fit_least_feasible_lockstep(
            "fpga-static",
            total,
            miss_tolerance,
            &mut |cands, bounded| {
                fit::run_candidate_batch(make, total, cfg, miss_tolerance, bounded, cands, &|j| {
                    Box::new(FpgaStatic::with_fleet(fleet_of(j)))
                })
            },
        ),
    };
    (r, fleet_of(j), stats)
}

/// Least feasible fleet size.
pub fn fit_fleet(trace: &AppTrace, cfg: &SimConfig, miss_tolerance: f64) -> u32 {
    search(&|| Box::new(trace.source()), cfg, miss_tolerance, FitEngine::Lockstep).1
}

/// Best-case static provisioning: the fitted policy for `trace`.
pub fn fitted(trace: &AppTrace, cfg: &SimConfig, miss_tolerance: f64) -> FpgaStatic {
    FpgaStatic::with_fleet(fit_fleet(trace, cfg, miss_tolerance))
}

/// [`fitted`] over a re-creatable source stream.
pub fn fitted_source(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    miss_tolerance: f64,
) -> FpgaStatic {
    FpgaStatic::with_fleet(search(make, cfg, miss_tolerance, FitEngine::Lockstep).1)
}

/// Fit and run: the search's best run plus the fitted fleet size. The
/// ideal baseline is rebased onto `defaults` — identical to re-running
/// the fitted configuration, without the extra simulation.
pub fn fit(
    trace: &AppTrace,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    fit_source(&|| Box::new(trace.source()), cfg, defaults, miss_tolerance)
}

/// [`fit`] over a re-creatable source stream.
pub fn fit_source(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    let (r, fleet, _stats) = fit_source_stats(make, cfg, defaults, miss_tolerance);
    (r, fleet)
}

/// [`fit_source`] that also surfaces the search's pass accounting (the
/// `spork bench-sim --fit` axis).
pub fn fit_source_stats(
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32, FitStats) {
    fit_source_stats_with(FitEngine::Lockstep, make, cfg, defaults, miss_tolerance)
}

/// [`fit_source_stats`] with an explicit engine choice (parity tests and
/// the bench's lockstep-vs-serial comparison; production callers take the
/// default).
pub fn fit_source_stats_with(
    engine: FitEngine,
    make: &MakeSource<'_>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32, FitStats) {
    let (mut r, fleet, stats) = search(make, cfg, miss_tolerance, engine);
    r.ideal = IdealBaseline::for_work(r.metrics.total_work, defaults);
    (r, fleet, stats)
}

/// [`fit`] against a cached [`WorkloadProfile`]: the oracle derives from
/// the profile's bins (no arrival streaming) and every pass replays the
/// shared materialized trace — re-traversal is a `Vec` iteration, so the
/// serial engine (fewest simulated candidates) wins here. Bit-identical
/// to [`fit`] on the profile's trace.
pub fn fit_profile(
    profile: &WorkloadProfile,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    miss_tolerance: f64,
) -> (RunResult, u32) {
    let oracle = Oracle::from_profile(profile, cfg, super::breakeven::Objective::energy());
    let (mut r, fleet, _stats) = search_with_oracle(
        &oracle,
        &|| Box::new(profile.source()),
        cfg,
        miss_tolerance,
        FitEngine::Serial,
    );
    r.ideal = IdealBaseline::for_work(r.metrics.total_work, defaults);
    (r, fleet)
}

impl Policy for FpgaStatic {
    fn name(&self) -> String {
        "fpga-static".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY // static: no periodic decisions
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        const KINDS: &[WorkerKind] = &[WorkerKind::Fpga];
        match obs {
            Observation::Start => {
                // Statically provisioned before the workload window (the
                // paper's static platform pays a "minor one-time spin-up
                // cost" but is ready when traffic starts).
                out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: self.fleet,
                    prewarmed: true,
                });
            }
            Observation::IdleExpired { worker } => {
                // Statically provisioned: the fleet is pinned until the
                // trace ends, then drains through the normal idle timeout.
                if view.trace_live() {
                    out.push(Action::KeepAlive { worker });
                }
            }
            Observation::Arrival { req } => {
                let to = match self.dispatcher.find(view, &req, KINDS) {
                    Some(w) => Target::Worker(w),
                    None => {
                        // FPGA-only: no CPU escape hatch. Best-effort onto
                        // the earliest-finishing FPGA (a deadline miss if
                        // truly full); if the entire fleet was reclaimed by
                        // the idle timeout (deep lull), re-provision.
                        match earliest_finishing(view, WorkerKind::Fpga) {
                            Some(w) => Target::Worker(w),
                            None => Target::Fresh(WorkerKind::Fpga),
                        }
                    }
                };
                out.push(Action::Dispatch { req, to });
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, SimConfig};
    use crate::sched::breakeven::Objective;
    use crate::sim;
    use crate::trace::synthetic_app;
    use crate::util::rng::Rng;

    #[test]
    fn provisions_peak_and_serves_fpga_only() {
        let mut rng = Rng::new(3);
        let trace = synthetic_app("f", &mut rng, 0.6, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let oracle = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let (r, fleet) = fit(&trace, &cfg, &PlatformConfig::paper_default(), 0.005);
        assert_eq!(r.metrics.on_cpu, 0);
        assert!(fleet >= oracle.peak());
        assert!(r.metrics.peak_fpgas >= oracle.peak());
        assert!(r.miss_fraction() < 0.05, "misses {}", r.miss_fraction());
    }

    #[test]
    fn uniform_load_is_energy_efficient_but_costly() {
        let mut rng = Rng::new(4);
        let trace = synthetic_app("f", &mut rng, 0.5, 600.0, 400.0, 0.010);
        let cfg = SimConfig::paper_default();
        let oracle = Oracle::from_trace(&trace, &cfg, Objective::energy());
        let r = sim::run(
            &trace,
            cfg,
            &PlatformConfig::paper_default(),
            &mut FpgaStatic::new(&oracle),
        );
        // At b=0.5 (uniform), static FPGA is near-ideal on energy.
        assert!(
            r.energy_efficiency() > 0.5,
            "efficiency {}",
            r.energy_efficiency()
        );
        // But pays for the full fleet the whole time.
        assert!(r.relative_cost() > 1.0);
    }
}
