//! Artifact manifest: the JSON index `python/compile/aot.py` writes next
//! to the HLO text files (names, input shapes/dtypes, model geometry).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactEntry>,
    /// Served-model geometry (layers) — informational.
    pub layers: Vec<usize>,
    pub batch_sizes: Vec<usize>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let model = j.get("model").context("manifest missing 'model'")?;
        let usize_arr = |v: &Json| -> Vec<usize> {
            v.as_arr()
                .map(|a| a.iter().filter_map(Json::as_u64).map(|x| x as usize).collect())
                .unwrap_or_default()
        };
        let layers = model.get("layers").map(usize_arr).unwrap_or_default();
        let batch_sizes = model.get("batch_sizes").map(usize_arr).unwrap_or_default();

        let arts = j
            .get("artifacts")
            .context("manifest missing 'artifacts'")?;
        let Json::Obj(entries) = arts else {
            anyhow::bail!("'artifacts' must be an object");
        };
        let mut artifacts = Vec::new();
        for (name, entry) in entries {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("artifact {name} missing file"))?
                .to_string();
            let args = entry
                .get("args")
                .and_then(Json::as_arr)
                .map(|list| {
                    list.iter()
                        .map(|a| ArgSpec {
                            shape: a.get("shape").map(usize_arr).unwrap_or_default(),
                            dtype: a.str_or("dtype", "float32").to_string(),
                        })
                        .collect()
                })
                .unwrap_or_default();
            artifacts.push(ArtifactEntry {
                name: name.clone(),
                file,
                args,
            });
        }
        Ok(Self {
            artifacts,
            layers,
            batch_sizes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"layers": [128, 256, 128], "batch_sizes": [8, 32], "weight_seed": 1},
      "artifacts": {
        "app_fpga_b8": {"file": "app_fpga_b8.hlo.txt",
                        "args": [{"shape": [8, 128], "dtype": "float32"}],
                        "hlo_bytes": 123},
        "predictor": {"file": "predictor.hlo.txt",
                      "args": [{"shape": [64], "dtype": "float32"},
                               {"shape": [64], "dtype": "float32"},
                               {"shape": [64], "dtype": "float32"},
                               {"shape": [9], "dtype": "float32"}],
                      "hlo_bytes": 9}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.layers, vec![128, 256, 128]);
        assert_eq!(m.batch_sizes, vec![8, 32]);
        assert_eq!(m.artifacts.len(), 2);
        let app = m.artifacts.iter().find(|a| a.name == "app_fpga_b8").unwrap();
        assert_eq!(app.args[0].shape, vec![8, 128]);
        assert_eq!(app.args[0].element_count(), 1024);
        let pred = m.artifacts.iter().find(|a| a.name == "predictor").unwrap();
        assert_eq!(pred.args.len(), 4);
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Manifest::from_json(&Json::parse("{}").unwrap()).is_err());
        let no_file = r#"{"model": {}, "artifacts": {"x": {}}}"#;
        assert!(Manifest::from_json(&Json::parse(no_file).unwrap()).is_err());
    }
}
