//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the PJRT CPU client, and
//! execute them from rust — Python never runs on the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`, unwrapping the 1-tuple the `return_tuple=True` lowering
//! produces.

mod artifacts;
mod executable;
#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;

pub use artifacts::{ArgSpec, ArtifactEntry, Manifest};
pub use executable::Executable;

// The `xla` name the runtime modules compile against: the real PJRT
// bindings under the `pjrt` feature, the in-tree stub otherwise (see
// pjrt_stub.rs and Cargo.toml for how to enable the real path).
#[cfg(feature = "pjrt")]
pub(crate) use ::xla;
#[cfg(not(feature = "pjrt"))]
pub(crate) use pjrt_stub as xla;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A PJRT client bound to an artifacts directory.
pub struct Runtime {
    client: Rc<xla::PjRtClient>,
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = Rc::new(xla::PjRtClient::cpu().context("creating PJRT CPU client")?);
        Ok(Self {
            client,
            dir,
            manifest,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact by manifest name into an executable.
    pub fn compile(&self, name: &str) -> Result<Executable> {
        let entry = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable::new(name.to_string(), exe, entry))
    }

    /// Names of available artifacts.
    pub fn names(&self) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect()
    }
}
