//! A compiled artifact with shape-checked f32 execution.

use super::artifacts::ArtifactEntry;
use super::xla;
use anyhow::{Context, Result};

pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
}

impl Executable {
    pub(crate) fn new(name: String, exe: xla::PjRtLoadedExecutable, entry: ArtifactEntry) -> Self {
        Self { name, exe, entry }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn arg_specs(&self) -> &[super::ArgSpec] {
        &self.entry.args
    }

    /// Execute with f32 inputs matching the manifest arg shapes; returns
    /// the flattened f32 outputs of the (single-element) result tuple.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            inputs.len() == self.entry.args.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.entry.args.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, spec)) in inputs.iter().zip(&self.entry.args).enumerate() {
            anyhow::ensure!(
                data.len() == spec.element_count(),
                "{}: input {i} has {} elements, expected {} (shape {:?})",
                self.name,
                data.len(),
                spec.element_count(),
                spec.shape
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshaping input {i} to {dims:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        // return_tuple=True lowering → unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Ok(out.to_vec::<f32>()?)
    }
}
