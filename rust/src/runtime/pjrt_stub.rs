//! Build-time stub for the `xla` PJRT bindings.
//!
//! The offline build environment does not ship the `xla` crate, so the
//! default (no-`pjrt`-feature) build compiles this module in its place:
//! the API surface `runtime/` uses, with every entry point that would
//! touch PJRT returning an error. Everything downstream already degrades
//! gracefully — `Runtime::load` fails before any compute, the serving
//! runtime reports the failure, and the artifact-gated tests/examples
//! skip when `artifacts/` is absent.
//!
//! Enabling the `pjrt` feature (plus the environment-provided `xla`
//! dependency — see Cargo.toml) swaps this stub for the real bindings
//! with no other source changes.

use anyhow::{anyhow, Result};

const UNAVAILABLE: &str =
    "spork was built without the `pjrt` feature: PJRT/XLA execution is unavailable \
     (simulation, solvers, and experiments are unaffected; see DESIGN.md)";

pub struct PjRtClient;

pub struct PjRtLoadedExecutable;

pub struct PjRtBuffer;

pub struct HloModuleProto;

pub struct XlaComputation;

#[derive(Clone)]
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(anyhow!(UNAVAILABLE))
    }
}
