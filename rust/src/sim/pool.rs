//! Worker pool: slab storage with per-kind live lists.
//!
//! The pool only stores workers; allocation/deallocation *policy* lives in
//! the schedulers and the engine drives state transitions.

use super::worker::{Worker, WorkerId, WorkerState};
use crate::config::WorkerKind;

#[derive(Debug, Default)]
pub struct Pool {
    slots: Vec<Option<Worker>>,
    free: Vec<u32>,
    live_cpu: Vec<WorkerId>,
    live_fpga: Vec<WorkerId>,
}

impl Pool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, make: impl FnOnce(WorkerId) -> Worker) -> WorkerId {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                (self.slots.len() - 1) as u32
            }
        };
        let id = WorkerId(idx);
        let w = make(id);
        match w.kind {
            WorkerKind::Cpu => self.live_cpu.push(id),
            WorkerKind::Fpga => self.live_fpga.push(id),
        }
        self.slots[idx as usize] = Some(w);
        id
    }

    pub fn remove(&mut self, id: WorkerId) -> Worker {
        let w = self.slots[id.0 as usize]
            .take()
            .expect("removing nonexistent worker");
        let live = match w.kind {
            WorkerKind::Cpu => &mut self.live_cpu,
            WorkerKind::Fpga => &mut self.live_fpga,
        };
        let pos = live.iter().position(|&x| x == id).expect("live list desync");
        live.swap_remove(pos);
        self.free.push(id.0);
        w
    }

    pub fn get(&self, id: WorkerId) -> Option<&Worker> {
        self.slots.get(id.0 as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, id: WorkerId) -> Option<&mut Worker> {
        self.slots.get_mut(id.0 as usize).and_then(|s| s.as_mut())
    }

    pub fn live_ids(&self, kind: WorkerKind) -> &[WorkerId] {
        match kind {
            WorkerKind::Cpu => &self.live_cpu,
            WorkerKind::Fpga => &self.live_fpga,
        }
    }

    pub fn iter_kind(&self, kind: WorkerKind) -> impl Iterator<Item = &Worker> + '_ {
        self.live_ids(kind).iter().map(move |&id| {
            self.get(id).expect("live list points at empty slot")
        })
    }

    pub fn iter_all(&self) -> impl Iterator<Item = &Worker> + '_ {
        self.iter_kind(WorkerKind::Cpu)
            .chain(self.iter_kind(WorkerKind::Fpga))
    }

    /// Live workers of a kind (any state).
    pub fn count(&self, kind: WorkerKind) -> u32 {
        self.live_ids(kind).len() as u32
    }

    /// Live workers excluding those spinning down, i.e. the "allocated"
    /// count schedulers reason about (spinning-up + active).
    pub fn allocated(&self, kind: WorkerKind) -> u32 {
        self.iter_kind(kind)
            .filter(|w| w.state != WorkerState::SpinningDown)
            .count() as u32
    }

    pub fn is_empty(&self) -> bool {
        self.live_cpu.is_empty() && self.live_fpga.is_empty()
    }

    pub fn total(&self) -> usize {
        self.live_cpu.len() + self.live_fpga.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(pool: &mut Pool, kind: WorkerKind) -> WorkerId {
        pool.insert(|id| Worker::new(id, kind, 0.0, 1.0, 0))
    }

    #[test]
    fn insert_remove_reuses_slots() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Fpga);
        assert_eq!(p.total(), 2);
        p.remove(a);
        assert_eq!(p.count(WorkerKind::Cpu), 0);
        let c = mk(&mut p, WorkerKind::Cpu);
        assert_eq!(c, a, "slot should be reused");
        assert!(p.get(b).is_some());
    }

    #[test]
    fn per_kind_lists() {
        let mut p = Pool::new();
        mk(&mut p, WorkerKind::Cpu);
        mk(&mut p, WorkerKind::Cpu);
        mk(&mut p, WorkerKind::Fpga);
        assert_eq!(p.count(WorkerKind::Cpu), 2);
        assert_eq!(p.count(WorkerKind::Fpga), 1);
        assert_eq!(p.iter_all().count(), 3);
    }

    #[test]
    fn allocated_excludes_spinning_down() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Fpga);
        mk(&mut p, WorkerKind::Fpga);
        p.get_mut(a).unwrap().state = WorkerState::SpinningDown;
        assert_eq!(p.count(WorkerKind::Fpga), 2);
        assert_eq!(p.allocated(WorkerKind::Fpga), 1);
    }

    #[test]
    #[should_panic]
    fn double_remove_panics() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        p.remove(a);
        p.remove(a);
    }
}
