//! Worker pool: slab storage with per-kind *ordered indexes*.
//!
//! The pool only stores workers; allocation/deallocation *policy* lives in
//! the schedulers and the engine drives state transitions. Three ordered
//! indexes ride on top of the slab so the engine's hot decisions are
//! O(log n) instead of scan-or-sort-per-decision:
//!
//! * **live** — the live ids of each kind, ordered by id. Serves
//!   [`PolicyView`](crate::policy::PolicyView) enumeration with an order
//!   that is deterministic *and* independent of removal history (the old
//!   swap-removed live list reshuffled on every retirement).
//! * **idle** — `(idle_since, id)` over Active workers with an empty
//!   queue: longest-idle-first retirement pops from the front instead of
//!   sorting the idle set on every `Retire` action.
//! * **ready** — `(busy_until, id)` over accepting (non-spinning-down)
//!   workers: the earliest-finishing fallback of capped dispatch is a
//!   range head instead of a full scan.
//!
//! Keys wrap [`OrdF64`] (IEEE `total_cmp`), so a NaN timestamp can never
//! panic a comparator mid-run — NaNs are rejected at trace validation.
//!
//! Index coherence is the pool's job: every mutation of an indexed field
//! must go through [`Pool::with_mut`], which re-keys the worker around
//! the closure. Direct `&mut Worker` access is deliberately not exposed.

use super::worker::{Worker, WorkerId, WorkerState};
use crate::config::WorkerKind;
use crate::util::ordf64::OrdF64;
use std::collections::BTreeSet;

type Key = (OrdF64, WorkerId);

/// Per-kind index slot.
const fn ix(kind: WorkerKind) -> usize {
    match kind {
        WorkerKind::Cpu => 0,
        WorkerKind::Fpga => 1,
    }
}

#[derive(Debug, Default)]
pub struct Pool {
    slots: Vec<Option<Worker>>,
    free: Vec<u32>,
    live: [BTreeSet<WorkerId>; 2],
    idle: [BTreeSet<Key>; 2],
    ready: [BTreeSet<Key>; 2],
    /// Live workers excluding spinning-down, per kind (the "allocated"
    /// count schedulers reason about), maintained O(1).
    allocated: [u32; 2],
}

impl Pool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `w`'s entries to the idle/ready indexes and allocated count.
    fn index_state(&mut self, w: &Worker) {
        let k = ix(w.kind);
        if w.state != WorkerState::SpinningDown {
            self.allocated[k] += 1;
            self.ready[k].insert((OrdF64(w.busy_until), w.id));
        }
        if w.state == WorkerState::Active && w.queued == 0 {
            self.idle[k].insert((OrdF64(w.idle_since), w.id));
        }
    }

    /// Remove `w`'s entries from the idle/ready indexes and allocated
    /// count (must mirror [`Self::index_state`] for the same snapshot).
    fn unindex_state(&mut self, w: &Worker) {
        let k = ix(w.kind);
        if w.state != WorkerState::SpinningDown {
            self.allocated[k] -= 1;
            let removed = self.ready[k].remove(&(OrdF64(w.busy_until), w.id));
            debug_assert!(removed, "ready index desync");
        }
        if w.state == WorkerState::Active && w.queued == 0 {
            let removed = self.idle[k].remove(&(OrdF64(w.idle_since), w.id));
            debug_assert!(removed, "idle index desync");
        }
    }

    pub fn insert(&mut self, make: impl FnOnce(WorkerId) -> Worker) -> WorkerId {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                (self.slots.len() - 1) as u32
            }
        };
        let id = WorkerId(idx);
        let w = make(id);
        debug_assert_eq!(w.id, id, "worker id must match its slot");
        self.live[ix(w.kind)].insert(id);
        self.index_state(&w);
        self.slots[idx as usize] = Some(w);
        id
    }

    pub fn remove(&mut self, id: WorkerId) -> Worker {
        let w = self.slots[id.0 as usize]
            .take()
            .expect("removing nonexistent worker");
        let was_live = self.live[ix(w.kind)].remove(&id);
        debug_assert!(was_live, "live index desync");
        self.unindex_state(&w);
        self.free.push(id.0);
        w
    }

    pub fn get(&self, id: WorkerId) -> Option<&Worker> {
        self.slots.get(id.0 as usize).and_then(|s| s.as_ref())
    }

    /// Mutate a worker while keeping the ordered indexes coherent: the
    /// worker is de-indexed, handed to `f`, and re-keyed from its new
    /// state. `f` must not change `id` or `kind` (debug-asserted). This
    /// is the only mutation path — there is no public `get_mut`.
    pub fn with_mut<R>(&mut self, id: WorkerId, f: impl FnOnce(&mut Worker) -> R) -> R {
        let slot = id.0 as usize;
        let mut w = self.slots[slot].take().expect("with_mut: unknown worker");
        self.unindex_state(&w);
        let (old_id, old_kind) = (w.id, w.kind);
        let r = f(&mut w);
        debug_assert!(
            w.id == old_id && w.kind == old_kind,
            "with_mut must not change identity"
        );
        self.index_state(&w);
        self.slots[slot] = Some(w);
        r
    }

    /// Live worker ids of `kind` (any state), ordered by id.
    pub fn live_ids(&self, kind: WorkerKind) -> Vec<WorkerId> {
        self.live[ix(kind)].iter().copied().collect()
    }

    pub fn iter_kind(&self, kind: WorkerKind) -> impl Iterator<Item = &Worker> + '_ {
        self.live[ix(kind)]
            .iter()
            .map(move |&id| self.get(id).expect("live index points at empty slot"))
    }

    pub fn iter_all(&self) -> impl Iterator<Item = &Worker> + '_ {
        self.iter_kind(WorkerKind::Cpu)
            .chain(self.iter_kind(WorkerKind::Fpga))
    }

    /// Idle (Active, empty-queue) workers of `kind`, longest-idle first —
    /// the retirement order, straight off the idle index.
    pub fn idle_ordered(&self, kind: WorkerKind) -> impl Iterator<Item = WorkerId> + '_ {
        self.idle[ix(kind)].iter().map(|&(_, id)| id)
    }

    /// Number of idle workers of `kind`.
    pub fn idle_count(&self, kind: WorkerKind) -> u32 {
        self.idle[ix(kind)].len() as u32
    }

    /// The earliest-finishing accepting worker of `kind` with its
    /// completion horizon, in O(log n) off the ready index.
    pub fn earliest_ready(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        self.ready[ix(kind)]
            .first()
            .map(|&(OrdF64(t), id)| (t, id))
    }

    /// The earliest-finishing accepting worker of any kind. CPU wins a
    /// cross-kind tie (matching the historical CPU-then-FPGA scan order).
    pub fn earliest_ready_any(&self) -> Option<WorkerId> {
        match (
            self.earliest_ready(WorkerKind::Cpu),
            self.earliest_ready(WorkerKind::Fpga),
        ) {
            (Some((tc, c)), Some((tf, f))) => Some(if tc <= tf { c } else { f }),
            (Some((_, c)), None) => Some(c),
            (None, Some((_, f))) => Some(f),
            (None, None) => None,
        }
    }

    /// Live workers of a kind (any state).
    pub fn count(&self, kind: WorkerKind) -> u32 {
        self.live[ix(kind)].len() as u32
    }

    /// Live workers excluding those spinning down, i.e. the "allocated"
    /// count schedulers reason about (spinning-up + active). O(1).
    pub fn allocated(&self, kind: WorkerKind) -> u32 {
        self.allocated[ix(kind)]
    }

    pub fn is_empty(&self) -> bool {
        self.live.iter().all(|l| l.is_empty())
    }

    pub fn total(&self) -> usize {
        self.live.iter().map(|l| l.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(pool: &mut Pool, kind: WorkerKind) -> WorkerId {
        pool.insert(|id| Worker::new(id, kind, 0.0, 1.0, 0))
    }

    /// Force a worker Active and idle at `since` (test scaffolding).
    fn activate(pool: &mut Pool, id: WorkerId, since: f64) {
        pool.with_mut(id, |w| {
            w.state = WorkerState::Active;
            w.ready_at = since;
            w.busy_until = since;
            w.idle_since = since;
        });
    }

    #[test]
    fn insert_remove_reuses_slots() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Fpga);
        assert_eq!(p.total(), 2);
        p.remove(a);
        assert_eq!(p.count(WorkerKind::Cpu), 0);
        let c = mk(&mut p, WorkerKind::Cpu);
        assert_eq!(c, a, "slot should be reused");
        assert!(p.get(b).is_some());
    }

    #[test]
    fn per_kind_lists() {
        let mut p = Pool::new();
        mk(&mut p, WorkerKind::Cpu);
        mk(&mut p, WorkerKind::Cpu);
        mk(&mut p, WorkerKind::Fpga);
        assert_eq!(p.count(WorkerKind::Cpu), 2);
        assert_eq!(p.count(WorkerKind::Fpga), 1);
        assert_eq!(p.iter_all().count(), 3);
    }

    #[test]
    fn allocated_excludes_spinning_down() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Fpga);
        mk(&mut p, WorkerKind::Fpga);
        p.with_mut(a, |w| w.state = WorkerState::SpinningDown);
        assert_eq!(p.count(WorkerKind::Fpga), 2);
        assert_eq!(p.allocated(WorkerKind::Fpga), 1);
    }

    #[test]
    fn idle_index_orders_longest_idle_first() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Cpu);
        let c = mk(&mut p, WorkerKind::Cpu);
        activate(&mut p, a, 5.0);
        activate(&mut p, b, 1.0);
        activate(&mut p, c, 3.0);
        let order: Vec<WorkerId> = p.idle_ordered(WorkerKind::Cpu).collect();
        assert_eq!(order, vec![b, c, a]);
        assert_eq!(p.idle_count(WorkerKind::Cpu), 3);
        // Giving b work drops it from the idle index.
        p.with_mut(b, |w| {
            w.assign(6.0, 1.0);
        });
        let order: Vec<WorkerId> = p.idle_ordered(WorkerKind::Cpu).collect();
        assert_eq!(order, vec![c, a]);
    }

    #[test]
    fn ready_index_tracks_busy_until() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Fpga); // busy_until = spin_up = 1.0
        let b = mk(&mut p, WorkerKind::Fpga);
        activate(&mut p, b, 0.0);
        p.with_mut(b, |w| {
            w.assign(0.0, 0.25); // busy_until = 0.25 < a's 1.0
        });
        assert_eq!(p.earliest_ready(WorkerKind::Fpga), Some((0.25, b)));
        p.with_mut(b, |w| {
            w.assign(0.0, 2.0); // now 2.25 > 1.0
        });
        assert_eq!(p.earliest_ready(WorkerKind::Fpga), Some((1.0, a)));
        // Spinning-down workers leave the ready index entirely.
        p.with_mut(a, |w| w.state = WorkerState::SpinningDown);
        assert_eq!(p.earliest_ready(WorkerKind::Fpga), Some((2.25, b)));
    }

    #[test]
    fn earliest_ready_any_prefers_cpu_on_tie() {
        let mut p = Pool::new();
        let f = mk(&mut p, WorkerKind::Fpga);
        let c = mk(&mut p, WorkerKind::Cpu);
        // Both have busy_until = 1.0 (same spin-up): CPU wins the tie.
        assert_eq!(p.earliest_ready_any(), Some(c));
        p.remove(c);
        assert_eq!(p.earliest_ready_any(), Some(f));
    }

    #[test]
    fn live_ids_are_id_ordered_and_removal_stable() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Cpu);
        let c = mk(&mut p, WorkerKind::Cpu);
        assert_eq!(p.live_ids(WorkerKind::Cpu), vec![a, b, c]);
        // Removing the middle worker must not reshuffle the rest (the old
        // swap-removed Vec moved `c` into `b`'s position).
        p.remove(b);
        assert_eq!(p.live_ids(WorkerKind::Cpu), vec![a, c]);
    }

    #[test]
    #[should_panic]
    fn double_remove_panics() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        p.remove(a);
        p.remove(a);
    }
}
