//! Worker pool: slab storage with per-kind *ordered indexes*.
//!
//! The pool only stores workers; allocation/deallocation *policy* lives in
//! the schedulers and the engine drives state transitions. Five ordered
//! indexes ride on top of the slab so the engine's hot decisions are
//! O(log n) instead of scan-or-sort-per-decision:
//!
//! * **live** — the live ids of each kind, ordered by id. Serves
//!   [`PolicyView`](crate::policy::PolicyView) enumeration with an order
//!   that is deterministic *and* independent of removal history (the old
//!   swap-removed live list reshuffled on every retirement).
//! * **idle** — `(idle_since, id)` over Active workers with an empty
//!   queue: longest-idle-first retirement pops from the front instead of
//!   sorting the idle set on every `Retire` action; the dispatch β→ι
//!   fallback takes the *tail* (most-recently-idle).
//! * **ready** — `(busy_until, id)` over accepting (non-spinning-down)
//!   workers: the earliest-finishing fallback of capped dispatch is a
//!   range head instead of a full scan.
//! * **busy** — `(busy_until, id)` over Active workers with queued work:
//!   Alg 3's "busiest feasible worker" is the tail of the deadline
//!   prefix `range(..=bound).next_back()` (see [`Pool::busiest_busy`]).
//! * **spinup** — `(queued_load, id)` over spinning-up workers: Alg 3's
//!   "most-loaded allocating worker" walks load groups from the tail —
//!   bounded by the (transient, small) spinning-up set, never fleet size.
//!
//! Keys wrap [`OrdF64`] (IEEE `total_cmp`), so a NaN timestamp can never
//! panic a comparator mid-run — NaNs are rejected at trace validation.
//!
//! **Tie-break contract.** Dispatch historically scanned workers in
//! ascending id order with strict `>` replacement, so equal-key extrema
//! resolve to the *lowest* id. Index keys are `(key, id)`, so an extremal
//! entry found with `next_back()` may carry the highest id of its key
//! group; every extremal query therefore finishes with a group-head
//! lookup (`range((key, WorkerId(0))..).next()`) to return the lowest id
//! of the extremal key — two O(log n) probes, scan-identical picks
//! (pinned by `rust/tests/dispatch_parity.rs`).
//!
//! Index coherence is the pool's job: every mutation of an indexed field
//! must go through [`Pool::with_mut`], which re-keys the worker around
//! the closure. Direct `&mut Worker` access is deliberately not exposed.

use super::worker::{Worker, WorkerId, WorkerState};
use crate::config::WorkerKind;
use crate::util::ordf64::OrdF64;
use std::collections::BTreeSet;

type Key = (OrdF64, WorkerId);

/// Per-kind index slot.
const fn ix(kind: WorkerKind) -> usize {
    match kind {
        WorkerKind::Cpu => 0,
        WorkerKind::Fpga => 1,
    }
}

#[derive(Debug, Default)]
pub struct Pool {
    slots: Vec<Option<Worker>>,
    free: Vec<u32>,
    live: [BTreeSet<WorkerId>; 2],
    idle: [BTreeSet<Key>; 2],
    ready: [BTreeSet<Key>; 2],
    /// Active workers with queued work, keyed `(busy_until, id)`.
    busy: [BTreeSet<Key>; 2],
    /// Spinning-up workers, keyed `(queued_load, id)` where queued_load =
    /// `busy_until - ready_at` (work already packed onto the allocation).
    spinup: [BTreeSet<Key>; 2],
    /// Live workers excluding spinning-down, per kind (the "allocated"
    /// count schedulers reason about), maintained O(1).
    allocated: [u32; 2],
    /// In-flight (queued + running) requests over live workers, per kind
    /// — the admission backlog, maintained O(1) so bounded-queue
    /// backpressure never scans the fleet per arrival.
    inflight: [u64; 2],
    /// Monotonic uid counter: slab slots (and ids) are recycled, uids never
    /// are. Stamped onto every inserted worker so in-flight events can
    /// detect that "their" slot was killed and reused (scenario faults).
    next_uid: u64,
}

/// The queued-load key of a spinning-up worker (work packed onto the
/// not-yet-ready allocation — Alg 3's α preference).
fn spinup_load(w: &Worker) -> f64 {
    w.busy_until - w.ready_at
}

impl Pool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `w`'s entries to the state-keyed indexes and allocated count.
    fn index_state(&mut self, w: &Worker) {
        let k = ix(w.kind);
        self.inflight[k] += w.queued as u64;
        if w.state != WorkerState::SpinningDown {
            self.allocated[k] += 1;
            self.ready[k].insert((OrdF64(w.busy_until), w.id));
        }
        match w.state {
            WorkerState::Active if w.queued == 0 => {
                self.idle[k].insert((OrdF64(w.idle_since), w.id));
            }
            WorkerState::Active => {
                self.busy[k].insert((OrdF64(w.busy_until), w.id));
            }
            WorkerState::SpinningUp => {
                self.spinup[k].insert((OrdF64(spinup_load(w)), w.id));
            }
            WorkerState::SpinningDown => {}
        }
    }

    /// Remove `w`'s entries from the state-keyed indexes and allocated
    /// count (must mirror [`Self::index_state`] for the same snapshot).
    fn unindex_state(&mut self, w: &Worker) {
        let k = ix(w.kind);
        self.inflight[k] -= w.queued as u64;
        if w.state != WorkerState::SpinningDown {
            self.allocated[k] -= 1;
            let removed = self.ready[k].remove(&(OrdF64(w.busy_until), w.id));
            debug_assert!(removed, "ready index desync");
        }
        match w.state {
            WorkerState::Active if w.queued == 0 => {
                let removed = self.idle[k].remove(&(OrdF64(w.idle_since), w.id));
                debug_assert!(removed, "idle index desync");
            }
            WorkerState::Active => {
                let removed = self.busy[k].remove(&(OrdF64(w.busy_until), w.id));
                debug_assert!(removed, "busy index desync");
            }
            WorkerState::SpinningUp => {
                let removed = self.spinup[k].remove(&(OrdF64(spinup_load(w)), w.id));
                debug_assert!(removed, "spinup index desync");
            }
            WorkerState::SpinningDown => {}
        }
    }

    pub fn insert(&mut self, make: impl FnOnce(WorkerId) -> Worker) -> WorkerId {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                (self.slots.len() - 1) as u32
            }
        };
        let id = WorkerId(idx);
        let mut w = make(id);
        debug_assert_eq!(w.id, id, "worker id must match its slot");
        w.uid = self.next_uid;
        self.next_uid += 1;
        self.live[ix(w.kind)].insert(id);
        self.index_state(&w);
        self.slots[idx as usize] = Some(w);
        id
    }

    pub fn remove(&mut self, id: WorkerId) -> Worker {
        let w = self.slots[id.0 as usize]
            .take()
            .expect("removing nonexistent worker");
        let was_live = self.live[ix(w.kind)].remove(&id);
        debug_assert!(was_live, "live index desync");
        self.unindex_state(&w);
        self.free.push(id.0);
        w
    }

    pub fn get(&self, id: WorkerId) -> Option<&Worker> {
        self.slots.get(id.0 as usize).and_then(|s| s.as_ref())
    }

    /// Mutate a worker while keeping the ordered indexes coherent: the
    /// worker is de-indexed, handed to `f`, and re-keyed from its new
    /// state. `f` must not change `id` or `kind` (debug-asserted). This
    /// is the only mutation path — there is no public `get_mut`.
    pub fn with_mut<R>(&mut self, id: WorkerId, f: impl FnOnce(&mut Worker) -> R) -> R {
        let slot = id.0 as usize;
        let mut w = self.slots[slot].take().expect("with_mut: unknown worker");
        self.unindex_state(&w);
        let (old_id, old_kind) = (w.id, w.kind);
        let r = f(&mut w);
        debug_assert!(
            w.id == old_id && w.kind == old_kind,
            "with_mut must not change identity"
        );
        self.index_state(&w);
        self.slots[slot] = Some(w);
        r
    }

    /// Live worker ids of `kind` (any state), ordered by id.
    pub fn live_ids(&self, kind: WorkerKind) -> Vec<WorkerId> {
        self.live[ix(kind)].iter().copied().collect()
    }

    /// Non-allocating counterpart of [`Self::live_ids`]: live ids of
    /// `kind` in ascending id order, straight off the live index.
    pub fn live_ids_iter(&self, kind: WorkerKind) -> impl Iterator<Item = WorkerId> + '_ {
        self.live[ix(kind)].iter().copied()
    }

    /// Live ids of `kind` strictly after `after`, ascending — the
    /// round-robin cursor's resume point, without materializing the list.
    pub fn live_ids_after(
        &self,
        kind: WorkerKind,
        after: WorkerId,
    ) -> impl Iterator<Item = WorkerId> + '_ {
        use std::ops::Bound::{Excluded, Unbounded};
        self.live[ix(kind)]
            .range((Excluded(after), Unbounded))
            .copied()
    }

    /// Lowest id carrying the extremal key `key` in `set` (the scan's
    /// lowest-id tie-break; see the module docs' tie-break contract).
    fn key_group_head(set: &BTreeSet<Key>, key: f64) -> Option<WorkerId> {
        set.range((OrdF64(key), WorkerId(0))..).next().map(|&(_, id)| id)
    }

    /// Busiest busy-Active worker of `kind` within the deadline prefix
    /// `busy_until <= bound`: max `busy_until`, lowest id on ties.
    /// Returns `(busy_until, id)`. Two O(log n) probes of the busy index.
    pub fn busiest_busy(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let set = &self.busy[ix(kind)];
        let &(OrdF64(b), _) = set.range(..=(OrdF64(bound), WorkerId(u32::MAX))).next_back()?;
        Self::key_group_head(set, b).map(|id| (b, id))
    }

    /// Most-recently-idle worker of `kind`: max `idle_since`, lowest id on
    /// ties. Returns `(idle_since, id)`. Idle workers always satisfy
    /// `busy_until <= now`, so deadline feasibility is uniform across the
    /// class and stays with the caller (`now + svc <= deadline`).
    pub fn most_recently_idle(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        let set = &self.idle[ix(kind)];
        let &(OrdF64(s), _) = set.last()?;
        Self::key_group_head(set, s).map(|id| (s, id))
    }

    /// Most-loaded spinning-up worker of `kind` with `busy_until <=
    /// bound`: max queued load, lowest feasible id on load ties. Returns
    /// `(queued_load, id)`. Walks load groups from the tail of the spinup
    /// index, checking feasibility per member — O(log n + inspected),
    /// bounded by the spinning-up set (transiently small: alloc rate ×
    /// spin-up window), never by fleet size.
    pub fn most_loaded_spinup(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let set = &self.spinup[ix(kind)];
        let mut next_group = set.last().map(|&(OrdF64(l), _)| l);
        while let Some(load) = next_group {
            let group = set.range((OrdF64(load), WorkerId(0))..=(OrdF64(load), WorkerId(u32::MAX)));
            for &(_, id) in group {
                let w = self.get(id).expect("spinup index points at empty slot");
                if w.busy_until <= bound {
                    return Some((load, id));
                }
            }
            next_group = set
                .range(..(OrdF64(load), WorkerId(0)))
                .next_back()
                .map(|&(OrdF64(l), _)| l);
        }
        None
    }

    /// Busiest feasible worker of `kind` over the *union* of busy-Active
    /// and spinning-up workers (AutoScale's packing order treats both as
    /// "busy", ranked by completion horizon): max `busy_until <= bound`,
    /// lowest id on ties. Returns `(busy_until, id)`. The busy side is two
    /// index probes; the spinning-up side walks its (small) set because it
    /// is keyed by queued load, not horizon.
    pub fn busiest_packed(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best = self.busiest_busy(kind, bound);
        for &(_, id) in &self.spinup[ix(kind)] {
            let w = self.get(id).expect("spinup index points at empty slot");
            let b = w.busy_until;
            if b <= bound
                && best.map_or(true, |(bb, bid)| b > bb || (b == bb && id < bid))
            {
                best = Some((b, id));
            }
        }
        best
    }

    pub fn iter_kind(&self, kind: WorkerKind) -> impl Iterator<Item = &Worker> + '_ {
        self.live[ix(kind)]
            .iter()
            .map(move |&id| self.get(id).expect("live index points at empty slot"))
    }

    pub fn iter_all(&self) -> impl Iterator<Item = &Worker> + '_ {
        self.iter_kind(WorkerKind::Cpu)
            .chain(self.iter_kind(WorkerKind::Fpga))
    }

    /// Idle (Active, empty-queue) workers of `kind`, longest-idle first —
    /// the retirement order, straight off the idle index.
    pub fn idle_ordered(&self, kind: WorkerKind) -> impl Iterator<Item = WorkerId> + '_ {
        self.idle[ix(kind)].iter().map(|&(_, id)| id)
    }

    /// Number of idle workers of `kind`.
    pub fn idle_count(&self, kind: WorkerKind) -> u32 {
        self.idle[ix(kind)].len() as u32
    }

    /// The earliest-finishing accepting worker of `kind` with its
    /// completion horizon, in O(log n) off the ready index.
    pub fn earliest_ready(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        self.ready[ix(kind)]
            .first()
            .map(|&(OrdF64(t), id)| (t, id))
    }

    /// The earliest-finishing accepting worker of any kind. CPU wins a
    /// cross-kind tie (matching the historical CPU-then-FPGA scan order).
    pub fn earliest_ready_any(&self) -> Option<WorkerId> {
        match (
            self.earliest_ready(WorkerKind::Cpu),
            self.earliest_ready(WorkerKind::Fpga),
        ) {
            (Some((tc, c)), Some((tf, f))) => Some(if tc <= tf { c } else { f }),
            (Some((_, c)), None) => Some(c),
            (None, Some((_, f))) => Some(f),
            (None, None) => None,
        }
    }

    /// Live workers of a kind (any state).
    pub fn count(&self, kind: WorkerKind) -> u32 {
        self.live[ix(kind)].len() as u32
    }

    /// Live workers excluding those spinning down, i.e. the "allocated"
    /// count schedulers reason about (spinning-up + active). O(1).
    pub fn allocated(&self, kind: WorkerKind) -> u32 {
        self.allocated[ix(kind)]
    }

    /// In-flight (queued + running) requests over live workers of `kind`.
    /// O(1).
    pub fn inflight(&self, kind: WorkerKind) -> u64 {
        self.inflight[ix(kind)]
    }

    /// Total in-flight requests over the whole pool — the admission
    /// backlog bounded-queue backpressure compares against. O(1).
    pub fn inflight_total(&self) -> u64 {
        self.inflight.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.live.iter().all(|l| l.is_empty())
    }

    pub fn total(&self) -> usize {
        self.live.iter().map(|l| l.len()).sum()
    }

    /// Assert every ordered index against ground truth recomputed from the
    /// slab. O(n log n) — test scaffolding for the index-coherence
    /// property suite (`util::prop`), not a hot-path check.
    pub fn check_coherence(&self) {
        for kind in WorkerKind::ALL {
            let k = ix(kind);
            let mut live = BTreeSet::new();
            let mut idle = BTreeSet::new();
            let mut ready = BTreeSet::new();
            let mut busy = BTreeSet::new();
            let mut spinup = BTreeSet::new();
            let mut allocated = 0u32;
            let mut inflight = 0u64;
            for w in self.slots.iter().flatten().filter(|w| w.kind == kind) {
                live.insert(w.id);
                inflight += w.queued as u64;
                if w.state != WorkerState::SpinningDown {
                    allocated += 1;
                    ready.insert((OrdF64(w.busy_until), w.id));
                }
                match w.state {
                    WorkerState::Active if w.queued == 0 => {
                        idle.insert((OrdF64(w.idle_since), w.id));
                    }
                    WorkerState::Active => {
                        busy.insert((OrdF64(w.busy_until), w.id));
                    }
                    WorkerState::SpinningUp => {
                        spinup.insert((OrdF64(spinup_load(w)), w.id));
                    }
                    WorkerState::SpinningDown => {}
                }
            }
            assert_eq!(self.live[k], live, "live index desync ({kind:?})");
            assert_eq!(self.idle[k], idle, "idle index desync ({kind:?})");
            assert_eq!(self.ready[k], ready, "ready index desync ({kind:?})");
            assert_eq!(self.busy[k], busy, "busy index desync ({kind:?})");
            assert_eq!(self.spinup[k], spinup, "spinup index desync ({kind:?})");
            assert_eq!(self.allocated[k], allocated, "allocated count desync ({kind:?})");
            assert_eq!(self.inflight[k], inflight, "inflight count desync ({kind:?})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(pool: &mut Pool, kind: WorkerKind) -> WorkerId {
        pool.insert(|id| Worker::new(id, kind, 0.0, 1.0, 0))
    }

    /// Force a worker Active and idle at `since` (test scaffolding).
    fn activate(pool: &mut Pool, id: WorkerId, since: f64) {
        pool.with_mut(id, |w| {
            w.state = WorkerState::Active;
            w.ready_at = since;
            w.busy_until = since;
            w.idle_since = since;
        });
    }

    #[test]
    fn insert_remove_reuses_slots() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Fpga);
        assert_eq!(p.total(), 2);
        p.remove(a);
        assert_eq!(p.count(WorkerKind::Cpu), 0);
        let c = mk(&mut p, WorkerKind::Cpu);
        assert_eq!(c, a, "slot should be reused");
        assert!(p.get(b).is_some());
    }

    #[test]
    fn uids_survive_slot_reuse() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let uid_a = p.get(a).unwrap().uid;
        p.remove(a);
        let b = mk(&mut p, WorkerKind::Cpu);
        assert_eq!(b, a, "slot should be reused");
        assert_ne!(p.get(b).unwrap().uid, uid_a, "uid must never be reused");
    }

    #[test]
    fn per_kind_lists() {
        let mut p = Pool::new();
        mk(&mut p, WorkerKind::Cpu);
        mk(&mut p, WorkerKind::Cpu);
        mk(&mut p, WorkerKind::Fpga);
        assert_eq!(p.count(WorkerKind::Cpu), 2);
        assert_eq!(p.count(WorkerKind::Fpga), 1);
        assert_eq!(p.iter_all().count(), 3);
    }

    #[test]
    fn inflight_counter_tracks_queued_work() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Fpga);
        activate(&mut p, a, 0.0);
        activate(&mut p, b, 0.0);
        assert_eq!(p.inflight_total(), 0);
        p.with_mut(a, |w| {
            w.assign(0.0, 1.0);
        });
        p.with_mut(a, |w| {
            w.assign(0.0, 1.0);
        });
        p.with_mut(b, |w| {
            w.assign(0.0, 2.0);
        });
        assert_eq!(p.inflight(WorkerKind::Cpu), 2);
        assert_eq!(p.inflight(WorkerKind::Fpga), 1);
        assert_eq!(p.inflight_total(), 3);
        p.with_mut(a, |w| {
            w.complete_one(2.0);
        });
        assert_eq!(p.inflight(WorkerKind::Cpu), 1);
        // Removal (retirement end, scenario kill) releases the backlog.
        p.remove(b);
        assert_eq!(p.inflight_total(), 1);
        p.check_coherence();
    }

    #[test]
    fn allocated_excludes_spinning_down() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Fpga);
        mk(&mut p, WorkerKind::Fpga);
        p.with_mut(a, |w| w.state = WorkerState::SpinningDown);
        assert_eq!(p.count(WorkerKind::Fpga), 2);
        assert_eq!(p.allocated(WorkerKind::Fpga), 1);
    }

    #[test]
    fn idle_index_orders_longest_idle_first() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Cpu);
        let c = mk(&mut p, WorkerKind::Cpu);
        activate(&mut p, a, 5.0);
        activate(&mut p, b, 1.0);
        activate(&mut p, c, 3.0);
        let order: Vec<WorkerId> = p.idle_ordered(WorkerKind::Cpu).collect();
        assert_eq!(order, vec![b, c, a]);
        assert_eq!(p.idle_count(WorkerKind::Cpu), 3);
        // Giving b work drops it from the idle index.
        p.with_mut(b, |w| {
            w.assign(6.0, 1.0);
        });
        let order: Vec<WorkerId> = p.idle_ordered(WorkerKind::Cpu).collect();
        assert_eq!(order, vec![c, a]);
    }

    #[test]
    fn ready_index_tracks_busy_until() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Fpga); // busy_until = spin_up = 1.0
        let b = mk(&mut p, WorkerKind::Fpga);
        activate(&mut p, b, 0.0);
        p.with_mut(b, |w| {
            w.assign(0.0, 0.25); // busy_until = 0.25 < a's 1.0
        });
        assert_eq!(p.earliest_ready(WorkerKind::Fpga), Some((0.25, b)));
        p.with_mut(b, |w| {
            w.assign(0.0, 2.0); // now 2.25 > 1.0
        });
        assert_eq!(p.earliest_ready(WorkerKind::Fpga), Some((1.0, a)));
        // Spinning-down workers leave the ready index entirely.
        p.with_mut(a, |w| w.state = WorkerState::SpinningDown);
        assert_eq!(p.earliest_ready(WorkerKind::Fpga), Some((2.25, b)));
    }

    #[test]
    fn earliest_ready_any_prefers_cpu_on_tie() {
        let mut p = Pool::new();
        let f = mk(&mut p, WorkerKind::Fpga);
        let c = mk(&mut p, WorkerKind::Cpu);
        // Both have busy_until = 1.0 (same spin-up): CPU wins the tie.
        assert_eq!(p.earliest_ready_any(), Some(c));
        p.remove(c);
        assert_eq!(p.earliest_ready_any(), Some(f));
    }

    #[test]
    fn live_ids_are_id_ordered_and_removal_stable() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Cpu);
        let c = mk(&mut p, WorkerKind::Cpu);
        assert_eq!(p.live_ids(WorkerKind::Cpu), vec![a, b, c]);
        // Removing the middle worker must not reshuffle the rest (the old
        // swap-removed Vec moved `c` into `b`'s position).
        p.remove(b);
        assert_eq!(p.live_ids(WorkerKind::Cpu), vec![a, c]);
    }

    #[test]
    #[should_panic]
    fn double_remove_panics() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        p.remove(a);
        p.remove(a);
    }

    /// Force a worker busy-Active with the given completion horizon.
    fn make_busy(pool: &mut Pool, id: WorkerId, busy_until: f64) {
        pool.with_mut(id, |w| {
            w.state = WorkerState::Active;
            w.ready_at = 0.0;
            w.busy_until = busy_until;
            w.queued = 1;
        });
    }

    #[test]
    fn busiest_busy_is_prefix_max_with_lowest_id_ties() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Fpga);
        let b = mk(&mut p, WorkerKind::Fpga);
        let c = mk(&mut p, WorkerKind::Fpga);
        make_busy(&mut p, a, 0.04);
        make_busy(&mut p, b, 0.02);
        make_busy(&mut p, c, 0.04); // ties with a on the horizon
        // Loose bound: busiest wins, lowest id (a) on the 0.04 tie.
        assert_eq!(p.busiest_busy(WorkerKind::Fpga, 1.0), Some((0.04, a)));
        // Tight bound excludes the 0.04 pair.
        assert_eq!(p.busiest_busy(WorkerKind::Fpga, 0.03), Some((0.02, b)));
        assert_eq!(p.busiest_busy(WorkerKind::Fpga, 0.01), None);
        // Idle and spinning-up workers never appear in the busy index.
        assert_eq!(p.busiest_busy(WorkerKind::Cpu, 1.0), None);
    }

    #[test]
    fn most_recently_idle_breaks_ties_to_lowest_id() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Cpu);
        let c = mk(&mut p, WorkerKind::Cpu);
        activate(&mut p, a, 3.0);
        activate(&mut p, b, 3.0); // ties with a
        activate(&mut p, c, 1.0);
        assert_eq!(p.most_recently_idle(WorkerKind::Cpu), Some((3.0, a)));
        p.with_mut(a, |w| w.queued = 1); // a leaves the idle class
        assert_eq!(p.most_recently_idle(WorkerKind::Cpu), Some((3.0, b)));
    }

    #[test]
    fn most_loaded_spinup_respects_feasibility_and_ties() {
        let mut p = Pool::new();
        // Three spinning-up FPGAs (spin_up 1.0): stagger ready_at so equal
        // loads carry different horizons.
        let a = mk(&mut p, WorkerKind::Fpga);
        let b = mk(&mut p, WorkerKind::Fpga);
        let c = mk(&mut p, WorkerKind::Fpga);
        p.with_mut(a, |w| w.assign(0.0, 0.5)); // load 0.5, horizon 1.5
        p.with_mut(b, |w| {
            w.ready_at = 2.0;
            w.busy_until = 2.5; // load 0.5, horizon 2.5 — ties a on load
        });
        p.with_mut(c, |w| w.assign(0.0, 0.2)); // load 0.2, horizon 1.2
        // Both 0.5-load workers feasible: lowest id (a) wins the tie.
        assert_eq!(p.most_loaded_spinup(WorkerKind::Fpga, 3.0), Some((0.5, a)));
        // Bound 2.0 cuts b out of its group; a still carries the max load.
        assert_eq!(p.most_loaded_spinup(WorkerKind::Fpga, 2.0), Some((0.5, a)));
        // Bound 1.4 kills the whole 0.5 group → next group (c).
        assert_eq!(p.most_loaded_spinup(WorkerKind::Fpga, 1.4), Some((0.2, c)));
        assert_eq!(p.most_loaded_spinup(WorkerKind::Fpga, 1.0), None);
    }

    #[test]
    fn busiest_packed_unions_busy_and_spinup() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu); // spinning up, horizon 1.0
        let b = mk(&mut p, WorkerKind::Cpu);
        make_busy(&mut p, b, 0.5);
        // Spin-up horizon (1.0) beats the busy worker's 0.5.
        assert_eq!(p.busiest_packed(WorkerKind::Cpu, 2.0), Some((1.0, a)));
        // Bound 0.8 excludes the spin-up → busy worker wins.
        assert_eq!(p.busiest_packed(WorkerKind::Cpu, 0.8), Some((0.5, b)));
        // Horizon tie between classes resolves to the lowest id.
        let c = mk(&mut p, WorkerKind::Cpu);
        make_busy(&mut p, c, 1.0);
        assert_eq!(p.busiest_packed(WorkerKind::Cpu, 2.0), Some((1.0, a)));
        p.check_coherence();
    }

    #[test]
    fn live_iterators_match_live_ids() {
        let mut p = Pool::new();
        let a = mk(&mut p, WorkerKind::Cpu);
        let b = mk(&mut p, WorkerKind::Cpu);
        let c = mk(&mut p, WorkerKind::Cpu);
        p.remove(b);
        let iter: Vec<WorkerId> = p.live_ids_iter(WorkerKind::Cpu).collect();
        assert_eq!(iter, p.live_ids(WorkerKind::Cpu));
        let after: Vec<WorkerId> = p.live_ids_after(WorkerKind::Cpu, a).collect();
        assert_eq!(after, vec![c]);
        assert_eq!(p.live_ids_after(WorkerKind::Cpu, c).count(), 0);
    }
}
