//! Event queue for the discrete-event simulator: a binary min-heap ordered
//! by event time with a deterministic tiebreak (sequence number), so runs
//! are bit-reproducible regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::worker::WorkerId;
use crate::config::WorkerKind;

/// Simulator events. Request arrivals are NOT events — the engine merges
/// the (already sorted) arrival array with this queue, which keeps the heap
/// small (its size tracks in-flight work, not total trace length).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A worker finished its spin-up and becomes available. `uid` is the
    /// worker's never-reused pool uid: scenario kills can free a slab slot
    /// with events still in flight, and the slot may be reused — a uid
    /// mismatch marks the event stale and it is dropped.
    SpinUpDone { worker: WorkerId, uid: u64 },
    /// A dispatched request finishes on `worker`. `seq` is the dispatch's
    /// never-reused sequence number (stamped by the engine, mirrored on the
    /// worker's in-flight entry): hedged duplicates are linked through it,
    /// so the first completion of a pair wins and the loser's completion
    /// only frees its worker.
    Completion {
        worker: WorkerId,
        uid: u64,
        seq: u64,
        arrival: f64,
        deadline: f64,
    },
    /// An idle timeout matures; `generation` guards against staleness (the
    /// worker may have received work since the timeout was scheduled) and
    /// `uid` against slot reuse after a scenario kill.
    IdleTimeout {
        worker: WorkerId,
        uid: u64,
        generation: u32,
    },
    /// A worker finished spinning down and leaves the pool.
    SpinDownDone { worker: WorkerId, uid: u64 },
    /// Scenario fault plan: a spot-preemption strike against `kind`. The
    /// victim is picked at execution time as `floor(victim_draw * n)` over
    /// the kind's live accepting workers (no-op when none exist).
    Preempted { kind: WorkerKind, victim_draw: f64 },
    /// Scenario fault plan: an independent (MTTF) hardware failure of one
    /// worker of `kind`; victim selection as in [`Event::Preempted`].
    WorkerFailed { kind: WorkerKind, victim_draw: f64 },
    /// Scenario fault plan: the spot price of `kind` stepped to `price`.
    PriceTick { kind: WorkerKind, price: f64 },
    /// A policy-deferred retry matures ([`crate::policy::Action::Defer`]):
    /// the engine re-offers `req` as [`crate::policy::Observation::RetryDue`].
    RetryDue { req: crate::policy::Request },
    /// A policy-scheduled timer fires ([`crate::policy::Action::Timer`]):
    /// the engine emits [`crate::policy::Observation::Timer`] with `token`.
    PolicyTimer { token: u64 },
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; ties broken by insertion order.
        // total_cmp keeps the hottest comparator in the simulator
        // panic-free: a NaN time is rejected loudly at `push` (debug) and
        // at the trace-validation boundary, never mid-heap-sift.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, event: Event) {
        // Hard assert (not debug): the heap comparator uses total_cmp and
        // will no longer panic on NaN, so this is the loud trip-wire for
        // non-finite event times from config-derived arithmetic (e.g. a
        // NaN service-time parameter) — one branch per push, negligible
        // next to the heap sift.
        assert!(time.is_finite(), "non-finite event time");
        self.heap.push(Entry {
            time,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(w: u32) -> Event {
        Event::SpinUpDone {
            worker: WorkerId(w),
            uid: w as u64,
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, ev(3));
        q.push(1.0, ev(1));
        q.push(2.0, ev(2));
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_broken_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5.0, ev(10));
        q.push(5.0, ev(20));
        q.push(5.0, ev(30));
        let ids: Vec<u32> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::SpinUpDone { worker, .. } => worker.0,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(ids, vec![10, 20, 30]);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(2.5, ev(1));
        q.push(0.5, ev(2));
        assert_eq!(q.peek_time(), Some(0.5));
        assert_eq!(q.pop().unwrap().0, 0.5);
        assert_eq!(q.len(), 1);
    }

    #[test]
    #[should_panic]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ev(1));
        q.push(1.0, ev(2));
        let _ = q.pop();
        let _ = q.pop();
    }
}
