//! Energy, cost, latency, and utilization accounting, plus the idealized
//! FPGA-only baseline the paper normalizes against.

use crate::config::{PlatformConfig, WorkerKind};
use crate::util::stats::Sample;

/// Per-worker-kind energy breakdown in joules (the MILP's E^a, E^b, E^i,
/// E^d components).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub alloc: f64,
    pub busy: f64,
    pub idle: f64,
    pub dealloc: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.alloc + self.busy + self.idle + self.dealloc
    }
}

/// Everything a simulation run measures.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub cpu_energy: EnergyBreakdown,
    pub fpga_energy: EnergyBreakdown,
    /// Occupancy cost in dollars per kind.
    pub cpu_cost: f64,
    pub fpga_cost: f64,
    pub requests: u64,
    pub on_cpu: u64,
    pub on_fpga: u64,
    pub deadline_misses: u64,
    pub cpu_spinups: u64,
    pub fpga_spinups: u64,
    /// Total work dispatched, in CPU-seconds (size units).
    pub total_work: f64,
    /// Latency sample (completion - arrival), subsampled.
    pub latency: Sample,
    /// Peak concurrently allocated workers.
    pub peak_cpus: u32,
    pub peak_fpgas: u32,
    /// Requests that actually completed (≤ `requests` under faults or
    /// load shedding; equal otherwise). Conservation once the run
    /// drains: `requests == completions + abandoned + shed`.
    pub completions: u64,
    /// Requests refused admission by the policy (`Action::Shed` — bounded
    /// admission queues under overload). Counted in `requests`, never
    /// dispatched, never completed; not a deadline miss (an explicit
    /// fast rejection, reported separately).
    pub shed: u64,
    /// Scenario faults: spot preemptions applied (a live worker existed).
    pub preemptions: u64,
    /// Scenario faults: independent hardware failures applied.
    pub worker_failures: u64,
    /// Lost in-flight requests re-offered to the policy after a kill.
    pub redispatches: u64,
    /// Lost in-flight requests dropped — retry budget or deadline
    /// exhausted. Each is also counted as a deadline miss.
    pub abandoned: u64,
    /// Executed-but-wasted worker-seconds destroyed by kills (service time
    /// already run on killed workers for requests that never completed).
    pub work_lost: f64,
    /// Hedged dispatches launched ([`crate::policy::Action::Hedge`] applied
    /// to a still-in-flight request). Each billed its duplicate's energy at
    /// dispatch whether or not it won.
    pub hedges: u64,
    /// Hedges whose *duplicate* finished first. Always ≤ `hedges`.
    pub hedge_wins: u64,
    /// Circuit-breaker openings ([`crate::policy::Action::Quarantine`]):
    /// a worker crossed K consecutive failures and was quarantined. A
    /// worker re-opened after a failed probe counts again.
    pub quarantines: u64,
    /// Completions that met their deadline *because* recovery intervened:
    /// the winning copy of a hedged pair, or a retried request
    /// (`attempt > 0`), finishing on time. The tentpole's headline number —
    /// deadline hits the fault would otherwise have destroyed.
    pub recovered_deadline_hits: u64,
}

impl Metrics {
    pub fn energy(&self, kind: WorkerKind) -> &EnergyBreakdown {
        match kind {
            WorkerKind::Cpu => &self.cpu_energy,
            WorkerKind::Fpga => &self.fpga_energy,
        }
    }

    pub fn energy_mut(&mut self, kind: WorkerKind) -> &mut EnergyBreakdown {
        match kind {
            WorkerKind::Cpu => &mut self.cpu_energy,
            WorkerKind::Fpga => &mut self.fpga_energy,
        }
    }

    pub fn total_energy(&self) -> f64 {
        self.cpu_energy.total() + self.fpga_energy.total()
    }

    pub fn total_cost(&self) -> f64 {
        self.cpu_cost + self.fpga_cost
    }

    pub fn cpu_request_fraction(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.on_cpu as f64 / self.requests as f64
        }
    }

    /// Merge another run's metrics (for aggregating across apps — §5.2:
    /// "energy consumption and costs are aggregated across all
    /// applications").
    pub fn merge(&mut self, o: &Metrics) {
        let add = |a: &mut EnergyBreakdown, b: &EnergyBreakdown| {
            a.alloc += b.alloc;
            a.busy += b.busy;
            a.idle += b.idle;
            a.dealloc += b.dealloc;
        };
        add(&mut self.cpu_energy, &o.cpu_energy);
        add(&mut self.fpga_energy, &o.fpga_energy);
        self.cpu_cost += o.cpu_cost;
        self.fpga_cost += o.fpga_cost;
        self.requests += o.requests;
        self.on_cpu += o.on_cpu;
        self.on_fpga += o.on_fpga;
        self.deadline_misses += o.deadline_misses;
        self.cpu_spinups += o.cpu_spinups;
        self.fpga_spinups += o.fpga_spinups;
        self.total_work += o.total_work;
        self.peak_cpus += o.peak_cpus; // pools are per-app → peaks add
        self.peak_fpgas += o.peak_fpgas;
        self.completions += o.completions;
        self.shed += o.shed;
        self.preemptions += o.preemptions;
        self.worker_failures += o.worker_failures;
        self.redispatches += o.redispatches;
        self.abandoned += o.abandoned;
        self.work_lost += o.work_lost;
        self.hedges += o.hedges;
        self.hedge_wins += o.hedge_wins;
        self.quarantines += o.quarantines;
        self.recovered_deadline_hits += o.recovered_deadline_hits;
    }
}

/// The idealized, best-case FPGA-only platform (§5.1 "Metrics"): incurs
/// only compute costs — zero spin-up and idling overheads — evaluated at
/// **default** Table 6 parameters regardless of the experiment's sweep.
#[derive(Clone, Copy, Debug)]
pub struct IdealBaseline {
    /// Joules for the whole workload.
    pub energy: f64,
    /// Dollars for the whole workload.
    pub cost: f64,
}

impl IdealBaseline {
    /// `total_work` is in CPU-seconds.
    pub fn for_work(total_work: f64, defaults: &PlatformConfig) -> Self {
        let fpga_seconds = total_work / defaults.fpga.speedup;
        IdealBaseline {
            energy: fpga_seconds * defaults.fpga.busy_power,
            cost: fpga_seconds * defaults.fpga.cost_per_sec(),
        }
    }
}

/// A finished run, normalized the way the paper reports results.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub scheduler: String,
    pub metrics: Metrics,
    pub ideal: IdealBaseline,
}

impl RunResult {
    /// Paper's "Energy Efficiency": ideal energy / measured energy (≤ 1 in
    /// practice; reported as a percentage). A degenerate run (no energy
    /// recorded, e.g. an empty trace) reads as 0.0, never NaN — ratio
    /// metrics feed ordered comparisons (the fitting searches' feasibility
    /// predicate among them) and a NaN would make every comparison
    /// silently false.
    pub fn energy_efficiency(&self) -> f64 {
        if self.metrics.total_energy() <= 0.0 {
            return 0.0;
        }
        self.ideal.energy / self.metrics.total_energy()
    }

    /// Paper's "Relative Cost": measured cost / ideal cost (≥ 1 typically).
    /// 0.0 (not NaN) when the ideal baseline is empty — see
    /// [`RunResult::energy_efficiency`].
    pub fn relative_cost(&self) -> f64 {
        if self.ideal.cost <= 0.0 {
            return 0.0;
        }
        self.metrics.total_cost() / self.ideal.cost
    }

    /// Fraction of requests that missed their deadline; 0.0 on a
    /// zero-request run (an empty workload is trivially feasible — a NaN
    /// here would poison the `miss_fraction() <= tolerance` feasibility
    /// comparison and its early-abort counterpart).
    pub fn miss_fraction(&self) -> f64 {
        if self.metrics.requests == 0 {
            0.0
        } else {
            self.metrics.deadline_misses as f64 / self.metrics.requests as f64
        }
    }
}

/// Largest miss count `m` such that `m / total <= tolerance` under the
/// *exact* f64 division [`RunResult::miss_fraction`] performs — the
/// integer inverse of the feasibility predicate. Deadline misses are
/// monotone over a run, so the instant a run's misses exceed this budget
/// its final miss fraction provably exceeds `tolerance`: aborting there
/// (see `run_source_bounded`) decides infeasibility without streaming
/// the rest of the trace. Computed by candidate-then-fixup rather than
/// plain `floor(tolerance * total)` so rounding can never disagree with
/// the final `miss_fraction() <= tolerance` comparison.
pub fn feasible_miss_budget(total: u64, tolerance: f64) -> u64 {
    if total == 0 || !(tolerance >= 0.0) {
        // Zero-request runs never miss; a NaN tolerance makes every
        // feasibility comparison false, so any miss must abort.
        return 0;
    }
    let total_f = total as f64;
    let mut m = ((tolerance * total_f).floor() as u64).min(total);
    while m > 0 && m as f64 / total_f > tolerance {
        m -= 1;
    }
    while m < total && (m + 1) as f64 / total_f <= tolerance {
        m += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total() {
        let e = EnergyBreakdown {
            alloc: 1.0,
            busy: 2.0,
            idle: 3.0,
            dealloc: 4.0,
        };
        assert_eq!(e.total(), 10.0);
    }

    #[test]
    fn ideal_baseline_default_params() {
        // 100 CPU-seconds of work at 2x speedup = 50 FPGA-seconds at 50 W.
        let d = PlatformConfig::paper_default();
        let b = IdealBaseline::for_work(100.0, &d);
        assert!((b.energy - 2500.0).abs() < 1e-9);
        assert!((b.cost - 50.0 * 0.982 / 3600.0).abs() < 1e-12);
    }

    #[test]
    fn efficiency_and_cost_ratios() {
        let d = PlatformConfig::paper_default();
        let mut m = Metrics::default();
        m.fpga_energy.busy = 5000.0;
        m.fpga_cost = 0.0273;
        m.requests = 10;
        m.deadline_misses = 1;
        let r = RunResult {
            scheduler: "test".into(),
            metrics: m,
            ideal: IdealBaseline::for_work(100.0, &d),
        };
        assert!((r.energy_efficiency() - 0.5).abs() < 1e-9);
        assert!((r.relative_cost() - 0.0273 / (50.0 * 0.982 / 3600.0)).abs() < 1e-6);
        assert!((r.miss_fraction() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn ratio_metrics_guard_degenerate_runs() {
        // A zero-request run must read as all-zeros, never NaN: the
        // fitting search's feasibility predicate (and its early-abort
        // budget) compare these values, and NaN comparisons are silently
        // false.
        let r = RunResult {
            scheduler: "empty".into(),
            metrics: Metrics::default(),
            ideal: IdealBaseline::for_work(0.0, &PlatformConfig::paper_default()),
        };
        assert_eq!(r.miss_fraction(), 0.0);
        assert_eq!(r.energy_efficiency(), 0.0);
        assert_eq!(r.relative_cost(), 0.0);
    }

    #[test]
    fn miss_budget_inverts_miss_fraction_exactly() {
        // For every (total, tolerance) probed: m <= budget iff m/total <=
        // tolerance — the budget is the exact integer inverse of the
        // feasibility comparison, never off by a rounding ulp.
        for &total in &[1u64, 3, 7, 100, 1000, 999_983] {
            for &tol in &[0.0, 0.005, 0.01, 1.0 / 3.0, 0.5, 1.0, 2.0] {
                let b = feasible_miss_budget(total, tol);
                assert!(b <= total);
                if b > 0 {
                    assert!((b as f64) / (total as f64) <= tol, "budget itself infeasible");
                }
                if b < total {
                    assert!(
                        ((b + 1) as f64) / (total as f64) > tol,
                        "budget not maximal: total={total} tol={tol} b={b}"
                    );
                }
            }
        }
        assert_eq!(feasible_miss_budget(0, 0.5), 0);
        assert_eq!(feasible_miss_budget(100, f64::NAN), 0);
    }

    #[test]
    fn merge_adds_components() {
        let mut a = Metrics::default();
        a.cpu_energy.busy = 10.0;
        a.requests = 5;
        a.on_cpu = 2;
        let mut b = Metrics::default();
        b.cpu_energy.busy = 5.0;
        b.fpga_cost = 1.0;
        b.requests = 3;
        b.on_cpu = 3;
        a.merge(&b);
        assert_eq!(a.cpu_energy.busy, 15.0);
        assert_eq!(a.fpga_cost, 1.0);
        assert_eq!(a.requests, 8);
        assert!((a.cpu_request_fraction() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn merge_in_fixed_index_order_is_bit_deterministic() {
        // The per-app parallel fan-outs (DESIGN.md §14) compute each
        // app's Metrics on whatever thread wins the permit race, then
        // merge them in *app-index order* on the caller. This pins the
        // contract that makes that bit-identical to the serial loop:
        // merge touches no order-sensitive state (no max/min tracking —
        // pools are per-app, so even the peaks add), so the only float
        // hazard is summation order, and index-order folding fixes that.
        let apps: Vec<Metrics> = (0..7u64)
            .map(|i| {
                let mut m = Metrics::default();
                // Awkward magnitudes so any reassociation of the sums
                // would actually flip low-order bits.
                m.cpu_energy.busy = 1e16 / (i as f64 + 1.0) + 0.1 * i as f64;
                m.fpga_energy.idle = (i as f64).exp() * 1e-7;
                m.fpga_cost = 1.0 / (3.0 + i as f64);
                m.total_work = (i as f64 + 1.0).sqrt();
                m.work_lost = 1e-3 / (i as f64 + 7.0);
                m.requests = 10 + i;
                m.peak_cpus = 2 + i as u32;
                m.peak_fpgas = 1 + i as u32;
                m
            })
            .collect();
        let fold = |ms: &[Metrics]| {
            let mut total = Metrics::default();
            for m in ms {
                total.merge(m);
            }
            total
        };
        let a = fold(&apps);
        let b = fold(&apps);
        assert_eq!(a.cpu_energy.busy.to_bits(), b.cpu_energy.busy.to_bits());
        assert_eq!(a.fpga_energy.idle.to_bits(), b.fpga_energy.idle.to_bits());
        assert_eq!(a.fpga_cost.to_bits(), b.fpga_cost.to_bits());
        assert_eq!(a.total_work.to_bits(), b.total_work.to_bits());
        assert_eq!(a.work_lost.to_bits(), b.work_lost.to_bits());
        assert_eq!(a.requests, b.requests);
        // Peaks are additive, not max-tracked: 2+3+..+8 and 1+2+..+7.
        assert_eq!(a.peak_cpus, (2..=8).sum::<u32>());
        assert_eq!(a.peak_fpgas, (1..=7).sum::<u32>());
        // And the fixed-order contract is load-bearing, not vacuous:
        // float merges do not reassociate. 1e16 + 1 + 1 stays 1e16 (each
        // 1.0 is a half-ulp tie that rounds back to even), while
        // 1 + 1 + 1e16 lands on the representable 1e16 + 2.
        let mk = |busy: f64| {
            let mut m = Metrics::default();
            m.cpu_energy.busy = busy;
            m
        };
        let forward = fold(&[mk(1e16), mk(1.0), mk(1.0)]);
        let backward = fold(&[mk(1.0), mk(1.0), mk(1e16)]);
        assert_ne!(
            forward.cpu_energy.busy.to_bits(),
            backward.cpu_energy.busy.to_bits(),
            "expected reassociated sums to differ in low-order bits"
        );
    }
}
