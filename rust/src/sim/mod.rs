//! Discrete-event simulator for hybrid FPGA-CPU platforms.
//!
//! The simulator executes one application's arrival trace against a
//! [`Policy`] implementation over a worker [`pool::Pool`], accounting
//! energy (alloc / busy / idle / dealloc), occupancy cost, and deadline
//! behaviour exactly as §5.1 of the paper specifies:
//!
//! * workers draw **busy power during spin up and spin down**;
//! * workers are kept idle for an allocation-duration timeout before being
//!   reclaimed;
//! * deadlines are 10x the request size (configurable);
//! * each application has its own pool (FPGAs are application-specific
//!   bitstreams), and experiment harnesses aggregate across apps.

pub mod engine;
pub mod event;
pub mod metrics;
pub mod pool;
pub mod worker;

pub use engine::{
    run, run_source, run_source_bounded, run_source_scenario, run_source_with_sink,
    run_sources_lockstep, run_with_sink, BoundedRun, Driver, SimState,
};
pub use metrics::{feasible_miss_budget, EnergyBreakdown, IdealBaseline, Metrics, RunResult};
pub use worker::{Worker, WorkerId, WorkerState};

// The scheduling interface lives in the transport-agnostic `policy`
// module (one policy API, many drivers); re-exported here because the
// simulator is its reference driver.
pub use crate::policy::{Policy, Request};
