//! Discrete-event simulator for hybrid FPGA-CPU platforms.
//!
//! The simulator executes one application's arrival trace against a
//! [`Scheduler`] implementation over a worker [`pool::Pool`], accounting
//! energy (alloc / busy / idle / dealloc), occupancy cost, and deadline
//! behaviour exactly as §5.1 of the paper specifies:
//!
//! * workers draw **busy power during spin up and spin down**;
//! * workers are kept idle for an allocation-duration timeout before being
//!   reclaimed;
//! * deadlines are 10x the request size (configurable);
//! * each application has its own pool (FPGAs are application-specific
//!   bitstreams), and experiment harnesses aggregate across apps.

pub mod engine;
pub mod event;
pub mod metrics;
pub mod pool;
pub mod worker;

pub use engine::{run, SimState};
pub use metrics::{EnergyBreakdown, IdealBaseline, Metrics, RunResult};
pub use worker::{Worker, WorkerId, WorkerState};

use crate::config::WorkerKind;

/// One request moving through the system. Sizes are known in advance
/// (paper §4.5); `deadline` is absolute.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub arrival: f64,
    /// Service time on a CPU worker, seconds.
    pub size: f64,
    pub deadline: f64,
}

/// Scheduler interface: the engine calls these hooks; implementations make
/// allocation and dispatch decisions through [`SimState`].
pub trait Scheduler {
    /// Machine name (matches `SchedulerKind::name()` where applicable).
    fn name(&self) -> String;

    /// Scheduling interval T_s. The engine ticks at t = 0, T_s, 2*T_s, ...
    /// while the trace is live. Return `f64::INFINITY` for purely reactive
    /// schedulers that don't want ticks.
    fn interval(&self) -> f64;

    /// Called once at t = 0 before any arrivals (pre-provisioning).
    fn on_start(&mut self, _sim: &mut SimState) {}

    /// Called at every interval boundary (t > 0).
    fn on_tick(&mut self, _sim: &mut SimState) {}

    /// Called for every arriving request; the implementation must dispatch
    /// it (possibly by spinning up a new worker — Alg 3 line 6).
    fn on_request(&mut self, req: Request, sim: &mut SimState);

    /// Consulted when a worker's idle timeout matures: return `true` to
    /// keep the worker alive for another timeout period (statically
    /// provisioned fleets / standing headroom), `false` to let it spin
    /// down. Defaults to the paper's universal idle-timeout reclamation.
    fn keep_alive(&self, _worker: WorkerId, _sim: &SimState) -> bool {
        false
    }

    /// Notification that a worker fully deallocated (after spin-down).
    /// `lifetime` is alloc→dealloc; `peers_at_alloc` is the same-kind
    /// allocated count at the worker's allocation (Spork's 𝕃 key).
    fn on_dealloc(
        &mut self,
        _kind: WorkerKind,
        _lifetime: f64,
        _peers_at_alloc: u32,
        _sim: &mut SimState,
    ) {
    }
}
